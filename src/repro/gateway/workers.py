"""Multi-worker gateway front: N processes behind ONE listening port.

The paper's accelerator replicates compute tiles until the datapath —
not any one module — sets throughput; the serving analogue is the
transport tier.  PR 3's :class:`~repro.gateway.server.GatewayServer`
runs everything on one asyncio loop in one process, so past a point the
Python transport (JSON framing + the GIL), not the compiled step, is the
ceiling.  :class:`WorkerFront` removes that ceiling the same way the
hardware does — by replication:

* **One port, N acceptors** — the front reserves a port with
  ``SO_REUSEPORT`` (bound, never listening, so the ephemeral port
  survives worker churn) and forks N worker processes that each bind the
  same address and ``listen()``; the kernel load-balances incoming
  connections across the listening sockets.  Every worker runs the same
  :class:`GatewayServer` code, so the wire behaviour is byte-identical
  across workers — bp1 binary frames for clients that negotiate them,
  the PR-3 JSON-lines protocol as per-connection fallback — and clients
  cannot tell one worker from eight (negotiation happens per connection,
  after the kernel has already picked the worker).
* **One engine per worker** — each worker builds its own
  ``AnomalyGateway`` (own ``Engine``, own compiled programs, own
  ``Placement`` shard when the factory asks for one) in its own process,
  so JAX dispatch, JSON parsing and the event loop all run N-way
  parallel with no shared GIL.
* **A tiny supervisor** — the parent process watches worker sentinels
  and respawns crashed workers on the same port (``restarts`` /
  ``sessions_lost`` account what the crash cost: the victim's
  last-heartbeat resident-session count), fans ``stats`` /
  ``recalibrate`` out over per-worker control pipes, and coordinates
  SIGTERM drain — every worker answers all pending tickets before exit
  and reports a drain summary (``dropped_tickets`` must be 0).

Control-plane message shapes (one ``multiprocessing.Pipe`` per worker):

  supervisor -> worker   ``{"id", "op": stats|recalibrate|control|
                         shutdown|ping, "kw": {...}}`` ->
                         ``{"id", "result"|"error"}``
  worker -> supervisor   ``{"event": ready|heartbeat|drained|error, ...}``
                         and ``{"wid", "op": aggregate|recalibrate_all,
                         "kw"}`` -> ``{"wid", "result"|"error"}`` — how a
                         wire-level ``stats``/``recalibrate`` request
                         received by ONE worker becomes a front-wide
                         fan-out (see ``GatewayServer.stats_provider``).

Session affinity is per-connection (the connection IS the stream, and a
connection lives on one worker), but with ``store_dir`` set the front is
DURABLE: every worker snapshots its pool block into its own shard of one
shared :class:`~repro.gateway.durability.SessionStore`, step responses
carry signed resumption tokens, a respawned worker adopts its dead
predecessor's snapshot shard, and clients revive a crashed worker's
streams on any other worker via ``resume`` — so ``sessions_lost`` counts
only what durability explicitly does not cover.  Coordinated drain takes
a handoff snapshot per worker first: the summary's
``sessions_migrated``/``sessions_lost`` account every resident stream.

``device_claims`` makes per-worker Placement shards an enforced
invariant instead of a convention: the supervisor validates the claim
map for overlap before spawning anything, and each worker registers its
claim in the store's :class:`~repro.gateway.claims.DeviceClaimRegistry`
at boot — two workers claiming one device is a boot error naming both.

Workers are spawned (not forked): JAX state must never be forked, and
``env`` overrides (e.g. ``XLA_FLAGS`` for a per-worker device mesh) are
applied to the environment the child boots with, before any JAX backend
initialisation.
"""
from __future__ import annotations

import itertools
import logging
import multiprocessing as mp
import os
import signal
import socket
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Callable, Optional

from repro.gateway.telemetry import REQUEST_HIST
from repro.obs import EventLog, Histogram, MetricsServer

logger = logging.getLogger(__name__)

_UNSET = object()


# ---------------------------------------------------------------------------
# worker process side
# ---------------------------------------------------------------------------


class _WorkerControl:
    """Worker-side end of the control pipe, living on the worker's event
    loop (``add_reader`` — no extra thread, so gateway calls stay on the
    loop and the single-threaded gateway contract holds)."""

    def __init__(self, conn, gateway, stop_event):
        self.conn = conn
        self.gateway = gateway
        self.stop_event = stop_event
        self._loop = None
        self._wid = itertools.count()
        self._futures: dict = {}

    def install(self, loop) -> None:
        self._loop = loop
        loop.add_reader(self.conn.fileno(), self._on_readable)

    def uninstall(self) -> None:
        if self._loop is not None:
            self._loop.remove_reader(self.conn.fileno())

    def send(self, msg: dict) -> None:
        try:
            self.conn.send(msg)
        except (BrokenPipeError, OSError):  # supervisor is gone; a drain
            pass                            # is already on its way

    def _on_readable(self) -> None:
        try:
            while self.conn.poll():
                self._handle(self.conn.recv())
        except (EOFError, OSError):
            # supervisor hung up: shut down rather than serve unowned
            self.stop_event.set()

    def _handle(self, msg: dict) -> None:
        if "wid" in msg:  # reply to a worker-initiated request
            fut = self._futures.pop(msg["wid"], None)
            if fut is not None and not fut.done():
                if "error" in msg:
                    fut.set_exception(RuntimeError(msg["error"]))
                else:
                    fut.set_result(msg["result"])
            return
        rid, op, kw = msg.get("id"), msg.get("op"), msg.get("kw", {})
        try:
            if op == "stats":
                result = self.gateway.stats()  # LOCAL stats: the supervisor
            elif op == "recalibrate":          # does the aggregation
                if kw.get("params") is not None:
                    # params crossed the pipe as numpy leaves (picklable);
                    # land them on-device once here so the hot pool step
                    # never pays a per-call host->device transfer
                    import jax
                    import jax.numpy as jnp

                    kw = dict(kw)
                    kw["params"] = jax.tree.map(jnp.asarray, kw["params"])
                result = self.gateway.recalibrate(**kw)
            elif op == "control":
                # batching-knob fan-out from the supervisor's control
                # loop; same path recalibrate takes, applied to the
                # batcher (clamped to the pre-compiled lane count)
                result = self.gateway.batcher.set_knobs(**kw)
            elif op == "shutdown":
                self.stop_event.set()
                result = {"ok": True}
            elif op == "ping":
                result = {"ok": True}
            else:
                raise ValueError(f"unknown control op {op!r}")
            self.send({"id": rid, "result": result})
        except Exception as exc:
            self.send({"id": rid, "error": f"{type(exc).__name__}: {exc}"})

    async def supervisor_request(self, op: str, timeout: float = 25.0, **kw):
        """Ask the supervisor for a front-wide operation (aggregate stats,
        fan-out recalibrate) and await its reply.  The default timeout
        sits ABOVE the supervisor's concurrent per-worker fan-out budget
        (15s, see ``WorkerFront._request``) so a slow sibling degrades to
        the supervisor's partial answer, not to this worker silently
        falling back mid-fan-out."""
        import asyncio

        wid = next(self._wid)
        fut = self._loop.create_future()
        self._futures[wid] = fut
        self.send({"wid": wid, "op": op, "kw": kw})
        try:
            return await asyncio.wait_for(fut, timeout)
        finally:
            self._futures.pop(wid, None)


def _worker_main(index: int, conn, host: str, port: int,
                 factory: Callable, heartbeat_s: float,
                 durability: Optional[dict] = None,
                 claim: Optional[dict] = None,
                 obs: Optional[dict] = None) -> None:
    """Entry point of one worker process: register the device claim,
    build the gateway, attach durability and the observability plane
    (per-worker event log + /metrics endpoint), serve the shared port,
    heartbeat, drain on SIGTERM/shutdown, report a summary."""
    import asyncio

    # factory() boots JAX and compiles programs — seconds during which a
    # coordinated drain's SIGTERM would hit the default disposition and
    # kill the worker uncleanly.  Flag boot-phase signals and honour them
    # the moment the event loop takes over signal handling.
    boot_stop = threading.Event()
    for _sig in (signal.SIGINT, signal.SIGTERM):
        signal.signal(_sig, lambda *_: boot_stop.set())

    from repro.gateway.server import GatewayServer

    owner = f"worker-{index}"
    obs = obs or {}
    metrics = None
    try:
        if claim:
            # validate-at-boot, BEFORE the expensive JAX/factory work: an
            # overlapping claim fails the spawn with the registry's error
            from repro.gateway.claims import DeviceClaimRegistry

            DeviceClaimRegistry(claim["dir"]).claim(owner, claim["devices"])
        gateway = factory()
        if durability:
            from repro.gateway.durability import enable_durability

            enable_durability(gateway, shard=owner, **durability)
        if obs.get("event_dir"):
            gateway.attach_event_log(
                os.path.join(obs["event_dir"], f"{owner}.jsonl"))
            gateway.events.emit("boot", worker=index, pid=os.getpid())
        if obs.get("metrics_port") is not None:
            # deterministic ladder off the supervisor's base port; a base
            # of 0 means every endpoint binds ephemerally (the bound port
            # travels back on the ready event)
            base = int(obs["metrics_port"])
            want = 0 if base == 0 else base + 1 + index
            try:
                metrics = MetricsServer(
                    gateway.stats, port=want,
                    labels={"worker": str(index)},
                ).start()
            except OSError as exc:
                # a scrape endpoint must never cost us an acceptor
                logger.warning("worker %d: /metrics bind on port %d failed "
                               "(%s); serving without metrics", index, want,
                               exc)
    except BaseException as exc:
        try:
            conn.send({"event": "error",
                       "message": f"{type(exc).__name__}: {exc}"})
        except Exception:
            pass
        raise

    async def _loop() -> None:
        loop = asyncio.get_running_loop()
        stop = asyncio.Event()
        control = _WorkerControl(conn, gateway, stop)

        async def _stats_provider():
            # a wire-level "stats" landing on THIS worker answers for the
            # whole front: the supervisor fans out to every worker (this
            # one replies its local stats from the pipe reader while this
            # coroutine awaits) and returns the aggregate.  If the
            # supervisor cannot answer, fall back to local stats rather
            # than failing the request.
            try:
                return await control.supervisor_request("aggregate")
            except Exception:
                logger.exception("worker %d: stats aggregation failed; "
                                 "answering local stats", index)
                return gateway.stats()

        async def _recalibrate_provider(**kw):
            # recalibrate must hit EVERY worker or thresholds diverge
            # across acceptors; no local fallback — a partial recalibrate
            # is worse than a failed one.
            return await control.supervisor_request("recalibrate_all", **kw)

        server = GatewayServer(
            gateway, host=host, port=port, reuse_port=True,
            stats_provider=_stats_provider,
            recalibrate_provider=_recalibrate_provider,
        )
        for sig in (signal.SIGINT, signal.SIGTERM):
            try:
                loop.add_signal_handler(sig, stop.set)
            except NotImplementedError:
                signal.signal(sig, lambda *_: stop.set())
        if boot_stop.is_set():  # a drain already asked for us mid-boot
            stop.set()
        control.install(loop)
        await server.start()
        control.send({"event": "ready", "index": index, "port": server.port,
                      "pid": os.getpid(),
                      "metrics_port": metrics.port if metrics else None})

        async def _heartbeat() -> None:
            while True:
                control.send({
                    "event": "heartbeat", "index": index,
                    "active": gateway.pool.active,
                    "queue_depth": gateway.batcher.queue_depth,
                })
                await asyncio.sleep(heartbeat_s)

        hb = loop.create_task(_heartbeat())
        await stop.wait()
        hb.cancel()
        active_before = gateway.pool.active
        await server.drain()  # durability: takes the handoff snapshot
        handoff = (gateway.durability.last_handoff
                   if gateway.durability is not None else None) or {}
        migrated = int(handoff.get("sessions_migrated", 0))
        counters = {k: float(v)
                    for k, v in gateway.stats()["counters"].items()}
        control.send({
            "event": "drained", "index": index,
            "summary": {
                "counters": counters,
                # the drain contract: nothing left unanswered
                "pending_after_drain": gateway.batcher.queue_depth,
                "active_before_drain": active_before,
                # the migration contract: with durability every resident
                # stream lands in the handoff snapshot (lost == 0)
                "sessions_migrated": migrated,
                "sessions_lost": max(0, active_before - migrated),
            },
        })
        control.uninstall()

    asyncio.run(_loop())
    if metrics is not None:
        try:
            metrics.stop()
        except Exception:
            logger.debug("worker %d: metrics server stop failed", index,
                         exc_info=True)
    if claim:
        try:
            from repro.gateway.claims import DeviceClaimRegistry

            DeviceClaimRegistry(claim["dir"]).release(owner)
        except Exception:
            logger.debug("worker %d: device-claim release failed (claim "
                         "may linger until reaped)", index, exc_info=True)


# ---------------------------------------------------------------------------
# supervisor side
# ---------------------------------------------------------------------------


class _Worker:
    """Supervisor-side record of one worker process (one generation)."""

    def __init__(self, index: int, proc, conn):
        self.index = index
        self.proc = proc
        self.conn = conn
        self.pid: Optional[int] = None
        self.metrics_port: Optional[int] = None
        self.ready = threading.Event()
        self.error: Optional[str] = None
        self.last_active = 0
        self.last_queue_depth = 0
        # set (under the front lock) the moment a scale-down picks this
        # worker: the monitor must not respawn its exit, and fan-outs /
        # stats must stop counting it BEFORE its SIGTERM lands
        self.scaling_down = False
        self.drain_summary: Optional[dict] = None
        self.exitcode: Optional[int] = None
        self.send_lock = threading.Lock()
        self.pending: dict = {}  # id -> [threading.Event, payload]

    def send(self, msg: dict) -> None:
        with self.send_lock:
            self.conn.send(msg)


class WorkerFront:
    """Supervise N ``GatewayServer`` worker processes behind one port.

    ``factory`` is called IN each worker process to build that worker's
    :class:`~repro.gateway.AnomalyGateway` — it must be picklable under
    the ``spawn`` start method (a module-level function or a
    ``functools.partial`` of one).  Each worker therefore owns a private
    engine; a factory that lays its engine out on
    ``Placement.from_spec("data=K")`` gives every worker its own K-device
    mesh shard (pass ``env={"XLA_FLAGS": ...}`` to emulate devices on
    CPU — the override is applied to the child's boot environment, ahead
    of any JAX initialisation).

    >>> front = WorkerFront(functools.partial(make_gateway), n_workers=4)
    >>> host, port = front.start()       # same wire protocol as one server
    >>> front.stats()                    # aggregated over the control pipes
    >>> summary = front.shutdown()       # coordinated drain; 0 dropped
    """

    def __init__(
        self,
        factory: Callable,
        *,
        n_workers: int = 2,
        host: str = "127.0.0.1",
        port: int = 0,
        env: Optional[dict] = None,
        heartbeat_ms: float = 250.0,
        respawn: bool = True,
        max_respawns: int = 8,
        store_dir: Optional[str] = None,
        snapshot_interval_ms: float = 1000.0,
        park_ttl_s: float = 900.0,
        token_ttl_s: Optional[float] = 3600.0,
        snapshot_keep: int = 2,
        device_claims: Optional[dict] = None,
        claims_dir: Optional[str] = None,
        event_dir: Optional[str] = None,
        metrics_port: Optional[int] = None,
    ):
        if n_workers < 1:
            raise ValueError(f"n_workers must be >= 1, got {n_workers}")
        if not hasattr(socket, "SO_REUSEPORT"):
            raise RuntimeError(
                "WorkerFront needs SO_REUSEPORT (Linux/BSD); this platform "
                "has no kernel-level listener load balancing"
            )
        self.factory = factory
        self.n_workers = n_workers
        self.host = host
        self.port = port
        self.env = dict(env or {})
        self.heartbeat_s = heartbeat_ms / 1e3
        self.respawn = respawn
        self.max_respawns = max_respawns
        # durable sessions: every worker snapshots into its own shard of
        # one shared store; None keeps the PR-5 lose-on-crash contract
        self.store_dir = None if store_dir is None else str(store_dir)
        self._durability_cfg = None
        if self.store_dir is not None:
            self._durability_cfg = {
                "directory": self.store_dir,
                "snapshot_interval_ms": float(snapshot_interval_ms),
                "park_ttl_s": float(park_ttl_s),
                "token_ttl_s": token_ttl_s,
                "keep": int(snapshot_keep),
            }
        # device-claim registry: {worker index: [device, ...]}, validated
        # for overlap HERE (fail before any worker boots) and enforced
        # again by each worker against the on-disk registry at boot
        self.device_claims = None
        self._claims_dir = None
        if device_claims is not None:
            from repro.gateway.claims import validate_disjoint

            claims = {int(i): list(devs) for i, devs in device_claims.items()}
            unknown = sorted(i for i in claims if not 0 <= i < n_workers)
            if unknown:
                raise ValueError(
                    f"device_claims for nonexistent worker index(es) "
                    f"{unknown} (n_workers={n_workers})"
                )
            validate_disjoint(
                {f"worker-{i}": devs for i, devs in claims.items()}
            )
            self._claims_dir = claims_dir or self.store_dir
            if self._claims_dir is None:
                raise ValueError(
                    "device_claims needs a registry directory: pass "
                    "claims_dir= (or store_dir=, which it defaults to)"
                )
            self.device_claims = claims
        # observability plane: a per-worker JSONL event log plus one
        # /metrics endpoint per process — supervisor (front aggregate) on
        # the base port, worker i on base+1+i (all ephemeral when base=0)
        self.event_dir = None if event_dir is None else str(event_dir)
        self.metrics_port = metrics_port if metrics_port is None else int(metrics_port)
        self._obs_cfg = None
        if self.event_dir is not None or self.metrics_port is not None:
            self._obs_cfg = {"event_dir": self.event_dir,
                             "metrics_port": self.metrics_port}
        self.metrics: Optional[MetricsServer] = None
        self._events = EventLog(None)
        self.restarts = 0
        self.sessions_lost = 0
        self.sessions_migrated = 0
        # autoscaling state: target_workers is the controller's current
        # setpoint (starts at the configured count); the control plane
        # (repro.control.ControlLoop) attaches itself here when enabled
        self.target_workers = n_workers
        self.scale_ups = 0
        self.scale_downs = 0
        self.control = None
        self._last_recalibrate: Optional[dict] = None
        self._last_batching: Optional[dict] = None
        self._ctx = mp.get_context("spawn")  # never fork a JAX parent
        self._workers: dict[int, _Worker] = {}
        self._reserve: Optional[socket.socket] = None
        self._rid = itertools.count()
        self._lock = threading.Lock()
        self._monitor: Optional[threading.Thread] = None
        self._executor: Optional[ThreadPoolExecutor] = None
        self._shutting_down = False
        self._started = False

    # -- lifecycle ---------------------------------------------------------

    def start(self, ready_timeout: float = 180.0) -> tuple:
        """Reserve the port, spawn the workers, wait until every worker's
        server is bound; returns ``(host, port)``."""
        if self._started:
            raise RuntimeError("front already started")
        self._reserve = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._reserve.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEPORT, 1)
        self._reserve.bind((self.host, self.port))
        self.host, self.port = self._reserve.getsockname()[:2]
        self._started = True
        if self.event_dir is not None:
            self._events = EventLog(
                os.path.join(self.event_dir, "supervisor.jsonl"))
            self._events.emit("boot", workers=self.n_workers,
                              host=self.host, port=self.port)
        # the executor services worker-initiated fan-outs (aggregate /
        # recalibrate_all); it must not run them on a pipe-reader thread
        # or the fan-out would deadlock waiting on its own reader
        self._executor = ThreadPoolExecutor(
            max_workers=max(2, self.n_workers), thread_name_prefix="front-ctl"
        )
        for i in range(self.n_workers):
            self._spawn(i)
        deadline = time.monotonic() + ready_timeout
        for w in list(self._workers.values()):
            while not w.ready.wait(0.2):
                if not w.proc.is_alive():  # died before binding (bad
                    w.proc.join(1.0)       # factory, import error, ...)
                    self._abort_start(
                        f"worker {w.index} exited with code "
                        f"{w.proc.exitcode} before becoming ready"
                        f"{': ' + w.error if w.error else ''}")
                if time.monotonic() > deadline:
                    self._abort_start(
                        f"worker {w.index} not ready after "
                        f"{ready_timeout:.0f}s "
                        f"({w.error or 'no error reported'})")
            if w.error is not None:
                self._abort_start(f"worker {w.index} failed to start: {w.error}")
        self._monitor = threading.Thread(
            target=self._monitor_loop, name="front-monitor", daemon=True
        )
        self._monitor.start()
        if self.metrics_port is not None:
            try:
                self.metrics = MetricsServer(
                    self.stats, host=self.host, port=self.metrics_port,
                    labels={"scope": "front"},
                ).start()
            except OSError as exc:
                logger.warning("front /metrics bind on port %d failed (%s); "
                               "per-worker endpoints are unaffected",
                               self.metrics_port, exc)
        return self.host, self.port

    def _abort_start(self, reason: str) -> None:
        self._shutting_down = True
        for w in self._workers.values():
            if w.proc.is_alive():
                w.proc.terminate()
        self._close_reserve()
        self._events.emit("abort", reason=reason)
        self._events.close()
        raise RuntimeError(reason)

    def _spawn(self, index: int) -> None:
        parent_conn, child_conn = self._ctx.Pipe()
        claim = None
        if self.device_claims is not None and index in self.device_claims:
            claim = {"dir": self._claims_dir,
                     "devices": self.device_claims[index]}
        proc = self._ctx.Process(
            target=_worker_main,
            args=(index, child_conn, self.host, self.port, self.factory,
                  self.heartbeat_s, self._durability_cfg, claim,
                  self._obs_cfg),
            name=f"gateway-worker-{index}",
            daemon=True,
        )
        worker = _Worker(index, proc, parent_conn)
        # written from start() AND the monitor thread (respawn) while
        # stats()/broadcasts iterate from other threads — keep the
        # insert under the class lock
        with self._lock:
            self._workers[index] = worker
        # env overrides (XLA_FLAGS et al.) must be in the child's boot
        # environment BEFORE any of its imports run — spawn inherits the
        # parent environment at exec time, so apply/restore around start()
        saved = {k: os.environ.get(k) for k in self.env}
        try:
            os.environ.update(self.env)
            proc.start()
        finally:
            for k, v in saved.items():
                if v is None:
                    os.environ.pop(k, None)
                else:
                    os.environ[k] = v
        child_conn.close()
        worker.pid = proc.pid
        threading.Thread(
            target=self._reader_loop, args=(worker,),
            name=f"front-reader-{index}", daemon=True,
        ).start()

    def _close_reserve(self) -> None:
        if self._reserve is not None:
            try:
                self._reserve.close()
            finally:
                self._reserve = None

    # -- supervisor threads ------------------------------------------------

    def _reader_loop(self, worker: _Worker) -> None:
        """Drain one worker's pipe: events update supervisor state,
        replies resolve pending requests, worker-initiated requests go to
        the executor."""
        while True:
            try:
                msg = worker.conn.recv()
            except (EOFError, OSError):
                return
            event = msg.get("event")
            if event == "ready":
                worker.pid = msg.get("pid", worker.pid)
                worker.metrics_port = msg.get("metrics_port")
                worker.ready.set()
            elif event == "heartbeat":
                worker.last_active = int(msg.get("active", 0))
                worker.last_queue_depth = int(msg.get("queue_depth", 0))
            elif event == "drained":
                worker.drain_summary = msg.get("summary")
            elif event == "error":
                worker.error = msg.get("message")
                worker.ready.set()  # unblock start() with the reason
            elif "wid" in msg:
                if self._executor is not None:
                    self._executor.submit(self._serve_worker_request,
                                          worker, msg)
            elif "id" in msg:
                pending = worker.pending.pop(msg["id"], None)
                if pending is not None:
                    pending[1] = msg
                    pending[0].set()

    def _serve_worker_request(self, worker: _Worker, msg: dict) -> None:
        """A worker asked for a front-wide operation; run the fan-out and
        reply over its pipe."""
        op, kw = msg.get("op"), msg.get("kw", {})
        try:
            if op == "aggregate":
                result = self.stats()
            elif op == "recalibrate_all":
                result = self.recalibrate(**kw)
            else:
                raise ValueError(f"unknown front op {op!r}")
            worker.send({"wid": msg["wid"], "result": result})
        except Exception as exc:
            try:
                worker.send({"wid": msg["wid"],
                             "error": f"{type(exc).__name__}: {exc}"})
            except Exception:
                logger.debug("worker %d: error reply failed (pipe gone?)",
                             worker.index, exc_info=True)

    def _monitor_loop(self) -> None:
        """Watch worker sentinels; respawn crashed workers (same index,
        same port) with session-loss accounting."""
        while not self._shutting_down:
            with self._lock:  # scale_down() removes entries concurrently
                workers = list(self._workers.values())
            sentinels = {w.proc.sentinel: w for w in workers
                         if w.proc.is_alive()}
            if not sentinels:
                time.sleep(0.05)
                continue
            dead = mp.connection.wait(list(sentinels), timeout=0.25)
            for s in dead:
                w = sentinels[s]
                w.proc.join(1.0)
                w.exitcode = w.proc.exitcode
                if self._shutting_down or w.drain_summary is not None \
                        or w.scaling_down:
                    continue  # a drained exit is handled by shutdown()
                    # (or by scale_down(), which owns its worker's drain)
                # with a snapshot store the victim's residents are not
                # lost — any worker can resume them from its shard — so
                # only count them against a front running without one
                durable = self._durability_cfg is not None
                with self._lock:
                    self.restarts += 1
                    if not durable:
                        self.sessions_lost += w.last_active
                logger.warning(
                    "worker %d (pid %s) died with exitcode %s; %d resident "
                    "session(s) %s; respawning",
                    w.index, w.pid, w.exitcode, w.last_active,
                    "resumable from snapshots" if durable else "lost",
                )
                self._events.emit(
                    "respawn", worker=w.index, pid=w.pid,
                    exitcode=w.exitcode, sessions_resident=w.last_active,
                    durable=durable,
                    respawned=(self.respawn
                               and self.restarts <= self.max_respawns),
                )
                if not self.respawn or self.restarts > self.max_respawns:
                    logger.error("worker %d not respawned (respawn=%s, "
                                 "restarts=%d)", w.index, self.respawn,
                                 self.restarts)
                    continue
                self._spawn(w.index)
                # do NOT block here waiting for readiness: a slow boot
                # must not leave the other workers' crashes unwatched —
                # a side thread waits and replays the live recalibration
                # (a respawn rebuilds from the factory, which would
                # otherwise quietly revert one acceptor to factory state)
                threading.Thread(
                    target=self._finish_respawn,
                    args=(self._workers[w.index],),
                    name=f"front-respawn-{w.index}", daemon=True,
                ).start()

    def _finish_respawn(self, worker: _Worker) -> None:
        """Off the monitor thread: wait (bounded) for the respawned
        worker and bring it back in line with the front's live state."""
        if not worker.ready.wait(180.0):
            logger.error("respawned worker %d never became ready",
                         worker.index)
            return
        if self._shutting_down:
            return
        if self._last_recalibrate is not None:
            try:
                self._request(worker, "recalibrate", **self._last_recalibrate)
                logger.info("worker %d: replayed live recalibration after "
                            "respawn", worker.index)
            except Exception:
                logger.exception("worker %d: recalibration replay failed — "
                                 "this acceptor serves factory thresholds",
                                 worker.index)
        if self._last_batching is not None:
            # same reasoning as recalibrate: a respawn rebuilds from the
            # factory's static knobs, which would quietly revert one
            # acceptor to the pre-adaptation operating point
            try:
                self._request(worker, "control", **self._last_batching)
            except Exception:
                logger.exception("worker %d: batching-knob replay failed — "
                                 "this acceptor serves factory knobs",
                                 worker.index)

    # -- control fan-out ---------------------------------------------------

    def _request(self, worker: _Worker, op: str, timeout: float = 15.0,
                 **kw) -> dict:
        rid = next(self._rid)
        pending = [threading.Event(), None]
        worker.pending[rid] = pending
        try:
            worker.send({"id": rid, "op": op, "kw": kw})
            if not pending[0].wait(timeout):
                raise TimeoutError(f"worker {worker.index}: {op} timed out "
                                   f"after {timeout:.0f}s")
        finally:
            worker.pending.pop(rid, None)
        reply = pending[1]
        if "error" in reply:
            raise RuntimeError(f"worker {worker.index}: {reply['error']}")
        return reply["result"]

    def _fan_out(self, op: str, **kw) -> tuple[list, int]:
        """Run ``op`` on every live worker CONCURRENTLY (wall time is the
        slowest worker, not the sum — the worker-side aggregate await is
        budgeted against one worker's timeout, see ``supervisor_request``);
        returns ``(answered, attempted)`` where ``answered`` is the
        ``(worker, result)`` pairs and ``attempted`` counts the live
        workers asked — callers that need all-or-nothing semantics
        (recalibrate) compare the two.  A worker mid-crash is skipped —
        the monitor is already respawning it."""
        with self._lock:  # snapshot: scale_down() mutates the map; its
            # scaling_down flag excludes the departing worker the moment
            # the decision lands, so no fan-out targets a draining worker
            targets = [w for w in self._workers.values()
                       if w.proc.is_alive() and w.ready.is_set()
                       and not w.scaling_down]
        slots: list = [None] * len(targets)

        def _one(i: int, w: _Worker) -> None:
            try:
                slots[i] = (w, self._request(w, op, **kw))
            except Exception:
                logger.exception("worker %d: %s fan-out failed", w.index, op)

        threads = [threading.Thread(target=_one, args=(i, w), daemon=True)
                   for i, w in enumerate(targets)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        return [s for s in slots if s is not None], len(targets)

    @property
    def alive_workers(self) -> int:
        with self._lock:
            return sum(1 for w in self._workers.values() if w.proc.is_alive())

    def worker_pids(self) -> list[int]:
        with self._lock:
            return [w.pid for w in self._workers.values() if w.proc.is_alive()]

    def stats(self) -> dict:
        """Aggregated front telemetry: per-worker ``gateway.stats()``
        snapshots (over the control pipes) plus summed pool/queue
        counters and capacities.  ``latency_ms`` percentiles are EXACT
        front-wide values: every worker ships its fixed-boundary latency
        histograms and the front sums bucket counts, which reproduces the
        histogram of the union of all workers' samples bit for bit (no
        worst-worker approximation); rate keys sum."""
        results, _ = self._fan_out("stats")
        per_worker = []
        for w, s in results:
            w.last_active = int(s.get("active_streams", w.last_active))
            per_worker.append({"index": w.index, "pid": w.pid,
                               "metrics_port": w.metrics_port, **s})
        counters: dict[str, float] = {}
        for _, s in results:
            for k, v in s.get("counters", {}).items():
                counters[k] = counters.get(k, 0.0) + float(v)
        merged: dict[str, Histogram] = {}
        for _, s in results:
            for name, data in (s.get("histograms") or {}).items():
                merged.setdefault(name, Histogram()).merge_from(
                    Histogram.from_dict(data))
        agg = {
            "workers": {
                "count": len(results),
                "configured": self.n_workers,
                "target": self.target_workers,
                "scale_ups": self.scale_ups,
                "scale_downs": self.scale_downs,
                "restarts": self.restarts,
                "sessions_lost": self.sessions_lost,
                "sessions_migrated": self.sessions_migrated,
                "durable": self.store_dir is not None,
            },
            "per_worker": per_worker,
            "counters": counters,
        }
        for key in ("capacity", "active_streams", "queue_depth"):
            agg[key] = int(sum(int(s.get(key, 0)) for _, s in results))
        # lifetime averages AND windowed rates both sum across workers
        # (the control plane reads the windowed keys)
        for key in ("requests_per_s", "stream_steps_per_s",
                    "arrival_rps_window", "completed_rps_window"):
            agg[key] = sum(float(s.get(key, 0.0)) for _, s in results)
        filled = counters.get("batch.filled", 0.0)
        slots = counters.get("batch.slots", 0.0)
        agg["batch_fill_ratio"] = filled / slots if slots else 0.0
        agg["histograms"] = {k: h.to_dict() for k, h in merged.items()}
        req = merged.get(REQUEST_HIST, Histogram())
        agg["latency_ms"] = {
            "count": req.count,
            "p50": req.percentile(50),
            "p95": req.percentile(95),
            "p99": req.percentile(99),
            "sum_ms": req.sum,
            "buckets": {str(i): n for i, n in sorted(req.counts.items())},
        }
        if results:
            first = results[0][1]
            for key in ("schedule", "threshold", "features", "max_batch",
                        "max_seq_len"):
                agg[key] = first.get(key)
        if self.control is not None:
            agg["control"] = self.control.describe()
        return agg

    def recalibrate(self, *, threshold=_UNSET, params=None, **kw) -> dict:
        """Fan a live recalibration out to EVERY worker (each worker owns
        a private engine/service, so a threshold swap must hit all of
        them or acceptors would disagree about alerts).  All-or-error: a
        PARTIAL application raises rather than reporting success, because
        divergent thresholds across acceptors are worse than a failed
        swap (retry until it answers for every worker).  The last fully
        applied recalibration is replayed onto respawned workers so a
        crash cannot quietly revert one acceptor to factory state.

        ``params`` swaps the MODEL on every worker: the pytree is copied
        to host numpy here (a pytree of device arrays does not pickle
        across the spawn boundary), shipped over each control pipe, and
        landed back on-device worker-side.  Resident sessions keep their
        slots and carried state, exactly like a threshold swap — and like
        a threshold swap, the params replay onto respawned workers."""
        if threshold is not _UNSET:
            kw["threshold"] = threshold
        if params is not None:
            import jax  # local: the supervisor normally never needs jax

            import numpy as np

            kw["params"] = jax.tree.map(lambda x: np.asarray(x), params)
        results, attempted = self._fan_out("recalibrate", **kw)
        if not results:
            raise RuntimeError("no live workers to recalibrate")
        if len(results) < attempted:
            raise RuntimeError(
                f"recalibrate reached only {len(results)}/{attempted} "
                f"workers — acceptors now disagree; retry to converge"
            )
        self._last_recalibrate = dict(kw)
        # close the respawn race: a worker that became ready DURING the
        # fan-out was not a target and _finish_respawn may have read the
        # previous _last_recalibrate — replay onto any ready worker the
        # fan-out missed before reporting success
        answered = {id(w) for w, _ in results}
        for w in list(self._workers.values()):
            if (w.proc.is_alive() and w.ready.is_set()
                    and id(w) not in answered):
                try:
                    self._request(w, "recalibrate", **kw)
                except Exception:
                    logger.exception("worker %d: post-fan-out recalibrate "
                                     "replay failed", w.index)
        out = dict(results[0][1])
        out["workers"] = len(results)
        return out

    def set_batching(self, max_batch: Optional[int] = None,
                     max_wait_ms: Optional[float] = None) -> dict:
        """Fan adjusted batching knobs out to every live worker (the
        control plane's actuation path; each worker clamps ``max_batch``
        to its pre-compiled lane count).  Best-effort by design — a
        worker mid-respawn picks the knobs up from the replay in
        ``_finish_respawn`` — and the last applied knobs are remembered
        for exactly that replay.  Returns the first worker's applied
        values plus the reach count."""
        kw = {}
        if max_batch is not None:
            kw["max_batch"] = int(max_batch)
        if max_wait_ms is not None:
            kw["max_wait_ms"] = float(max_wait_ms)
        if not kw:
            raise ValueError("nothing to set: pass max_batch or max_wait_ms")
        results, attempted = self._fan_out("control", **kw)
        with self._lock:
            merged = dict(self._last_batching or {})
            merged.update(kw)
            self._last_batching = merged
        out = dict(results[0][1]) if results else dict(kw)
        out["workers"] = len(results)
        out["attempted"] = attempted
        return out

    # -- autoscaling -------------------------------------------------------

    def scale_up(self, ready_timeout: float = 180.0) -> dict:
        """Add one worker (lowest unused index) on the same shared port.

        Reuses the respawn machinery: the new worker builds from the
        factory, then the live recalibration and batching knobs are
        replayed onto it so it serves the front's CURRENT operating
        point, not factory state.  Blocks until the worker is ready (it
        only starts taking kernel-balanced connections once it listens).
        """
        if not self._started:
            raise RuntimeError("front not started")
        with self._lock:
            if self._shutting_down:
                raise RuntimeError("front is shutting down")
            index = 0
            while index in self._workers:
                index += 1
            self.target_workers = len(self._workers) + 1
            self.scale_ups += 1
        self._spawn(index)
        worker = self._workers[index]
        if not worker.ready.wait(ready_timeout):
            raise TimeoutError(
                f"scale-up worker {index} not ready after {ready_timeout:.0f}s"
                f" ({worker.error or 'no error reported'})"
            )
        if worker.error is not None:
            raise RuntimeError(f"scale-up worker {index} failed: {worker.error}")
        for op, kw in (("recalibrate", self._last_recalibrate),
                       ("control", self._last_batching)):
            if kw is not None:
                try:
                    self._request(worker, op, **kw)
                except Exception:
                    logger.exception("worker %d: %s replay after scale-up "
                                     "failed", index, op)
        self._events.emit("scale_up", worker=index, pid=worker.pid,
                          workers=self.alive_workers)
        return {"index": index, "pid": worker.pid,
                "workers": self.alive_workers}

    def scale_down(self, timeout: float = 60.0) -> dict:
        """Remove one worker (highest live index) via the zero-drop drain.

        This is the PR-6 coordinated drain applied to a single worker,
        never a kill: the victim stops being a fan-out/stats target the
        moment it is chosen (``scaling_down``, set under the lock —
        capacity figures update atomically with the decision, so no
        admission-facing snapshot ever counts a departing worker), gets
        SIGTERM, answers every pending ticket, hands its resident
        sessions off to the snapshot store when durability is on, and
        reports the same summary fields a full-front shutdown reports:
        ``dropped_tickets`` / ``sessions_migrated`` / ``sessions_lost``.
        """
        if not self._started:
            raise RuntimeError("front not started")
        with self._lock:
            live = [w for w in self._workers.values()
                    if w.proc.is_alive() and w.ready.is_set()
                    and not w.scaling_down]
            if len(live) <= 1:
                raise RuntimeError(
                    f"cannot scale below one worker ({len(live)} live)"
                )
            victim = max(live, key=lambda w: w.index)
            victim.scaling_down = True
            self.target_workers = len(live) - 1
            self.scale_downs += 1
        try:
            os.kill(victim.pid, signal.SIGTERM)
        except (ProcessLookupError, OSError):
            pass
        victim.proc.join(timeout)
        if victim.proc.is_alive():
            logger.error("worker %d did not drain in %.0fs during "
                         "scale-down; terminating", victim.index, timeout)
            victim.proc.terminate()
            victim.proc.join(5.0)
        victim.exitcode = victim.proc.exitcode
        if victim.exitcode == 0 and victim.drain_summary is None:
            # same settle as shutdown(): the reader thread may not have
            # consumed the buffered "drained" event yet
            settle = time.monotonic() + 2.0
            while victim.drain_summary is None and time.monotonic() < settle:
                time.sleep(0.01)
        summary = victim.drain_summary
        clean = victim.exitcode == 0 and summary is not None
        if clean:
            dropped = int(summary.get("pending_after_drain", 0))
            migrated = int(summary.get("sessions_migrated", 0))
            lost = int(summary.get("sessions_lost", 0))
        else:
            dropped = victim.last_queue_depth
            migrated = 0
            lost = victim.last_active
        with self._lock:
            self._workers.pop(victim.index, None)
            self.sessions_migrated += migrated
            self.sessions_lost += lost
        self._events.emit("scale_down", worker=victim.index,
                          pid=victim.pid, clean=clean,
                          dropped_tickets=dropped,
                          sessions_migrated=migrated, sessions_lost=lost,
                          workers=self.alive_workers)
        return {
            "index": victim.index, "pid": victim.pid,
            "exitcode": victim.exitcode, "clean": clean,
            "dropped_tickets": dropped,
            "sessions_migrated": migrated,
            "sessions_lost": lost,
            "workers": self.alive_workers,
        }

    # -- shutdown ----------------------------------------------------------

    def shutdown(self, timeout: float = 120.0) -> dict:
        """Coordinated drain: SIGTERM every worker, wait for each to
        answer all pending tickets and exit, aggregate the drain
        summaries.  Returns the front summary: ``dropped_tickets`` is the
        sum of tickets left unanswered (0 on a clean drain; a
        force-terminated worker contributes its last-heartbeat queue
        depth), while ``counters`` cover only CLEANLY drained workers — a
        terminated worker's lifetime counters die with it, so on a
        partial drain the totals undercount served traffic (the per-entry
        ``exits`` list says which workers are covered)."""
        if not self._started:
            raise RuntimeError("front not started")
        self._shutting_down = True
        if self.control is not None:
            try:  # stop the control thread first: no scale decisions
                self.control.stop()  # may race a drain in progress
            except Exception:
                logger.exception("control loop stop failed during shutdown")
            self.control = None
        deadline = time.monotonic() + timeout
        with self._lock:
            workers = list(self._workers.values())
        for w in workers:
            if not w.proc.is_alive():
                continue
            # a worker still booting (e.g. just respawned) has no signal
            # handling installed yet — give it a bounded chance to come
            # up so its drain is clean rather than a raw SIGTERM death
            if not w.ready.is_set():
                w.ready.wait(min(60.0, max(0.1, deadline - time.monotonic())))
            try:
                os.kill(w.pid, signal.SIGTERM)
            except (ProcessLookupError, OSError):
                # already exited — the goal state; join below records it
                logger.debug("worker %d: SIGTERM at shutdown found it gone",
                             w.index)
        exits = []
        dropped = 0
        counters: dict[str, float] = {}
        clean = 0
        migrated = 0
        drain_lost = 0
        for w in workers:
            w.proc.join(max(0.1, deadline - time.monotonic()))
            if w.proc.is_alive():  # a worker stuck mid-drain: last resort
                logger.error("worker %d did not drain in time; terminating",
                             w.index)
                w.proc.terminate()
                w.proc.join(5.0)
            w.exitcode = w.proc.exitcode
            if w.exitcode == 0 and w.drain_summary is None:
                # the process is gone but its reader thread may not have
                # consumed the buffered "drained" event yet — give it a
                # beat before declaring the exit unclean
                settle = time.monotonic() + 2.0
                while w.drain_summary is None and time.monotonic() < settle:
                    time.sleep(0.01)
            summary = w.drain_summary
            is_clean = w.exitcode == 0 and summary is not None
            if is_clean:
                clean += 1
                dropped += int(summary.get("pending_after_drain", 0))
                migrated += int(summary.get("sessions_migrated", 0))
                drain_lost += int(summary.get("sessions_lost", 0))
                for k, v in summary.get("counters", {}).items():
                    counters[k] = counters.get(k, 0.0) + float(v)
            else:
                # a worker that died or was force-terminated mid-drain
                # never answered its parked tickets; its last-heartbeat
                # queue depth is the best accounting of what it dropped
                dropped += w.last_queue_depth
                drain_lost += w.last_active
            exits.append({
                "index": w.index, "pid": w.pid, "exitcode": w.exitcode,
                "clean": is_clean,
                "pending_after_drain": (summary or {}).get(
                    "pending_after_drain"),
                "active_before_drain": (summary or {}).get(
                    "active_before_drain"),
            })
        if self._executor is not None:
            self._executor.shutdown(wait=False)
            self._executor = None
        if self.metrics is not None:
            try:
                self.metrics.stop()
            finally:
                self.metrics = None
        self._close_reserve()
        self.sessions_migrated += migrated
        self._events.emit("drain", clean_exits=clean,
                          dropped_tickets=dropped,
                          sessions_migrated=migrated,
                          sessions_lost=self.sessions_lost + drain_lost)
        self._events.close()
        return {
            # the workers present AT shutdown (autoscaling may have moved
            # the fleet away from the configured n_workers)
            "workers": len(workers),
            "clean_exits": clean,
            "dropped_tickets": dropped,
            "restarts": self.restarts,
            # migration accounting: with durability a clean drain reports
            # sessions_migrated == residents and adds 0 to sessions_lost;
            # without it, drain-dropped residents count as lost (they
            # were, exactly as before — now it is visible)
            "sessions_migrated": migrated,
            "sessions_lost": self.sessions_lost + drain_lost,
            "counters": counters,
            "exits": exits,
        }

    def run_until_signal(
        self, on_ready: Optional[Callable[["WorkerFront"], None]] = None
    ) -> dict:
        """start() -> wait for SIGINT/SIGTERM on the supervisor ->
        coordinated drain; returns the shutdown summary.  The launcher's
        serve loop for ``--workers N``.

        Handlers are installed BEFORE start() and stay installed through
        the drain: a SIGTERM while workers are still booting (JAX import
        + compile take seconds) must queue a clean shutdown, and a second
        SIGTERM during the drain must be a no-op — not a
        default-disposition kill that drops every pending ticket."""
        stop = threading.Event()
        previous = {}
        for sig in (signal.SIGINT, signal.SIGTERM):
            previous[sig] = signal.signal(sig, lambda *_: stop.set())
        try:
            self.start()
            if on_ready is not None:
                on_ready(self)
            stop.wait()
            return self.shutdown()
        finally:
            for sig, handler in previous.items():
                signal.signal(sig, handler)

    def __repr__(self) -> str:
        state = "started" if self._started else "new"
        return (f"WorkerFront(workers={self.n_workers}, alive="
                f"{self.alive_workers}, {self.host}:{self.port}, {state}, "
                f"restarts={self.restarts})")


def default_gateway_factory(
    arch: str = "lstm-ae-f32-d2",
    schedule: str = "wavefront",
    *,
    reduced: bool = False,
    train_steps: int = 0,
    train_seq_len: int = 64,
    capacity: int = 32,
    max_batch: int = 16,
    max_wait_ms: float = 5.0,
    max_queue: int = 1024,
    mesh: int = 1,
    warm_seq_len: int = 0,
    priority_classes: int = 1,
    tenant_rate: Optional[float] = None,
    tenant_burst: Optional[float] = None,
) -> "object":
    """Picklable per-worker gateway builder (the launcher's ``--workers``,
    benchmarks, smoke, tests).

    Runs IN the worker process: builds an :class:`AnomalyService` on
    ``schedule`` (optionally laid out on a ``mesh``-way data placement),
    optionally fits + calibrates it — every worker re-fits
    deterministically from the same seed, so all workers serve identical
    params without shipping arrays across processes — and opens a
    gateway.  ``warm_seq_len > 0`` pre-compiles that score bucket before
    the worker reports ready, so kernel connection balancing never lands
    traffic on a cold worker.
    """
    import numpy as np

    from repro.config import get_config, reduced_config
    from repro.data import TimeseriesConfig
    from repro.engine import AnomalyService, EngineConfig, Placement

    cfg = reduced_config(arch) if reduced else get_config(arch)
    sched = (EngineConfig(schedule=schedule, placement=Placement.data(mesh))
             if mesh > 1 else schedule)
    svc = AnomalyService(cfg, schedule=sched)
    if train_steps:
        fit_cfg = TimeseriesConfig(features=svc.features,
                                   seq_len=train_seq_len, batch=64)
        svc.fit(fit_cfg, train_steps)
        svc.calibrate(fit_cfg)
    gw = svc.open_gateway(capacity=capacity, max_batch=max_batch,
                          max_wait_ms=max_wait_ms, max_queue=max_queue)
    if priority_classes > 1 or tenant_rate is not None:
        # worker-side admission: shedding must happen where requests
        # arrive.  Batching/autoscaling run supervisor-side (ControlLoop)
        # so no SLO here — this gateway's control is admission-only.
        from repro.control import ControlConfig, enable_control

        enable_control(gw, ControlConfig(
            priority_classes=priority_classes,
            tenant_rate=tenant_rate, tenant_burst=tenant_burst,
        ))
    if warm_seq_len > 0:
        warm = np.zeros((max_batch, warm_seq_len, svc.features), np.float32)
        gw.score(list(warm))
        gw.telemetry.reset()  # warm-up is not traffic: served counters,
        #                       fill ratios and drain summaries start at 0
    return gw


__all__ = ["WorkerFront", "default_gateway_factory"]

"""bp1 — the gateway's length-prefixed binary wire format.

The JSON-lines protocol (PR 3) spends ~94% of achievable wire throughput
on text framing and float-list (de)serialization.  ``bp1`` replaces the
hot path with fixed binary frames whose float32 payloads land in the
micro-batcher's bucket pad buffer via ``np.frombuffer`` — zero copy, no
intermediate lists.

Frame layout (all integers little-endian)::

    offset  size  field
    0       2     magic        b"\\xb1P"
    2       1     version      1
    3       1     opcode       see OP_* below
    4       4     flags        bit0 RESPONSE, bit1 ERROR
    8       8     req_id       client-chosen; responses echo it
    16      4     payload_len  bytes following the header
    20      ...   payload      u32 meta_len | meta (UTF-8 JSON) | data

``meta`` is a compact JSON object carrying the same fields the JSON-lines
protocol would put in its request/response dict (minus ``op``/``id``,
which live in the header).  ``data`` is opcode-specific raw bytes:

* ``SCORE`` requests pack ``n`` windows of shape ``(t, f)`` as
  contiguous ``<f4`` (meta: ``{"n", "t", "f"}``); responses return ``n``
  float32 scores.
* ``STEP`` requests pack ``t`` samples of ``f`` features each (meta:
  ``{"t"}``); responses return ``t`` float32 running errors.
* every other opcode is a "generic meta frame": empty ``data``, the
  whole message in ``meta`` — which lets the server reuse the JSON-era
  ``_op_*`` handlers unchanged.

Negotiation: a binary client opens the connection with the 4-byte
``PREAMBLE`` line ``b"\\xb1P1\\n"``.  A bp1-capable server switches the
connection to frame mode and answers with a ``HELLO`` response frame; a
legacy JSON-lines server cannot decode the preamble as UTF-8 and answers
a JSON error line (first byte ``{``), which the client detects and falls
back to JSON on the same connection.  The preamble is intentionally not
valid JSON *and* not valid UTF-8 so no legacy exchange can collide with
it.

This module's codec core is stdlib-only (``struct`` + ``json``) so the
CI ``lint`` job can run the conformance corpus and frame fuzzer without
installing numpy/jax; the float32 helpers import numpy lazily.
"""
from __future__ import annotations

import json
import struct
from typing import Any, Iterator, NamedTuple

MAGIC = b"\xb1P"
VERSION = 1
#: What a binary client writes first.  Read by the server's JSON readline
#: loop (it ends in \n); invalid UTF-8, so a legacy server answers a JSON
#: error line instead of crashing — that mismatch is the fallback signal.
PREAMBLE = MAGIC + b"1\n"

#: magic(2s) version(B) opcode(B) flags(I) req_id(Q) payload_len(I)
HEADER = struct.Struct("<2sBBIQI")
HEADER_SIZE = HEADER.size  # 20 bytes

FLAG_RESPONSE = 0x1
FLAG_ERROR = 0x2

#: req_id used for connection-level frames that answer no particular
#: request (the HELLO greeting, framing-error notices).  Clients must
#: never use it for a request.
NO_REQUEST_ID = 0xFFFFFFFFFFFFFFFF

OP_HELLO = 0x01
OP_PING = 0x02
OP_SCORE = 0x03
OP_STEP = 0x04
OP_CLOSE = 0x05
OP_RESUME = 0x06
OP_RECALIBRATE = 0x07
OP_STATS = 0x08
OP_SNAPSHOT = 0x09

OPCODE_BY_NAME = {
    "hello": OP_HELLO,
    "ping": OP_PING,
    "score": OP_SCORE,
    "step": OP_STEP,
    "close": OP_CLOSE,
    "resume": OP_RESUME,
    "recalibrate": OP_RECALIBRATE,
    "stats": OP_STATS,
    "snapshot": OP_SNAPSHOT,
}
NAME_BY_OPCODE = {code: name for name, code in OPCODE_BY_NAME.items()}

#: Default cap on a single frame's payload; mirrors GatewayServer's
#: max_line_bytes so neither protocol can make the server buffer more
#: than the other.
DEFAULT_MAX_FRAME_BYTES = 16 << 20

_META_LEN = struct.Struct("<I")


class WireProtocolError(ValueError):
    """A frame violated the bp1 format (bad magic/version, impossible
    length, malformed payload container).  Framing-level instances mean
    byte alignment is lost and the connection must be dropped;
    payload-level instances (raised after a complete frame was read) are
    answerable with an error frame."""


class Frame(NamedTuple):
    opcode: int
    flags: int
    req_id: int
    payload: bytes

    @property
    def is_response(self) -> bool:
        return bool(self.flags & FLAG_RESPONSE)

    @property
    def is_error(self) -> bool:
        return bool(self.flags & FLAG_ERROR)


def pack_header(opcode: int, flags: int, req_id: int, payload_len: int) -> bytes:
    return HEADER.pack(MAGIC, VERSION, opcode, flags, req_id, payload_len)


def pack_payload(meta: dict[str, Any] | None, data: bytes = b"") -> bytes:
    """u32 meta_len | compact sorted-key JSON | raw data.

    Sorted keys + compact separators make encoding deterministic, which
    the conformance corpus relies on for byte-exact comparisons.  A
    frame with no meta and no data packs to an empty payload.
    """
    if not meta and not data:
        return b""
    meta_bytes = b"" if not meta else json.dumps(
        meta, separators=(",", ":"), sort_keys=True
    ).encode("utf-8")
    return _META_LEN.pack(len(meta_bytes)) + meta_bytes + bytes(data)


def pack_frame(
    opcode: int,
    req_id: int,
    meta: dict[str, Any] | None = None,
    data: bytes = b"",
    flags: int = 0,
) -> bytes:
    payload = pack_payload(meta, data)
    return pack_header(opcode, flags, req_id, len(payload)) + payload


def unpack_header(buf: bytes | bytearray | memoryview) -> tuple[int, int, int, int]:
    """-> (opcode, flags, req_id, payload_len); raises WireProtocolError
    on short input, bad magic, or unsupported version."""
    if len(buf) < HEADER_SIZE:
        raise WireProtocolError(
            f"short header: {len(buf)} bytes, need {HEADER_SIZE}"
        )
    magic, version, opcode, flags, req_id, payload_len = HEADER.unpack_from(buf)
    if magic != MAGIC:
        raise WireProtocolError(f"bad magic {bytes(magic)!r}")
    if version != VERSION:
        raise WireProtocolError(f"unsupported bp1 version {version}")
    return opcode, flags, req_id, payload_len


def split_payload(payload: bytes | memoryview) -> tuple[dict[str, Any], memoryview]:
    """Split a frame payload into (meta dict, data view).

    The returned data is a memoryview into ``payload`` — no copy — which
    is what lets ``np.frombuffer`` hand the batcher a view of the recv
    buffer.
    """
    view = memoryview(payload)
    if len(view) == 0:
        return {}, view
    if len(view) < _META_LEN.size:
        raise WireProtocolError("payload shorter than its meta_len prefix")
    (meta_len,) = _META_LEN.unpack_from(view)
    if _META_LEN.size + meta_len > len(view):
        raise WireProtocolError(
            f"meta_len {meta_len} overruns payload of {len(view)} bytes"
        )
    if meta_len == 0:
        meta: dict[str, Any] = {}
    else:
        try:
            meta = json.loads(bytes(view[_META_LEN.size:_META_LEN.size + meta_len]))
        except (ValueError, UnicodeDecodeError) as exc:
            raise WireProtocolError(f"meta is not valid JSON: {exc}") from None
        if not isinstance(meta, dict):
            raise WireProtocolError("meta must be a JSON object")
    return meta, view[_META_LEN.size + meta_len:]


class FrameReader:
    """Incremental frame decoder for a byte stream.

    Feed it arbitrary chunks; it yields complete frames and raises
    WireProtocolError the moment the stream stops being bp1 — critically,
    *before* buffering a payload whose advertised length exceeds
    ``max_frame_bytes`` (an adversarial length field must not cause a
    giant allocation).
    """

    def __init__(self, max_frame_bytes: int = DEFAULT_MAX_FRAME_BYTES) -> None:
        self.max_frame_bytes = int(max_frame_bytes)
        self._buf = bytearray()

    def feed(self, chunk: bytes) -> list[Frame]:
        self._buf += chunk
        return list(self._drain())

    def _drain(self) -> Iterator[Frame]:
        while len(self._buf) >= HEADER_SIZE:
            opcode, flags, req_id, payload_len = unpack_header(self._buf)
            if payload_len > self.max_frame_bytes:
                raise WireProtocolError(
                    f"payload_len {payload_len} exceeds max frame "
                    f"size {self.max_frame_bytes}"
                )
            end = HEADER_SIZE + payload_len
            if len(self._buf) < end:
                return
            payload = bytes(self._buf[HEADER_SIZE:end])
            del self._buf[:end]
            yield Frame(opcode, flags, req_id, payload)

    @property
    def pending_bytes(self) -> int:
        return len(self._buf)


async def read_frame(reader, max_frame_bytes: int = DEFAULT_MAX_FRAME_BYTES) -> Frame:
    """Read one frame from an asyncio StreamReader.

    Raises asyncio.IncompleteReadError on EOF (clean or mid-frame) and
    WireProtocolError on bad magic/version or an oversize length field —
    checked before the payload is read, so a hostile header never makes
    the server allocate its advertised length.
    """
    header = await reader.readexactly(HEADER_SIZE)
    opcode, flags, req_id, payload_len = unpack_header(header)
    if payload_len > max_frame_bytes:
        raise WireProtocolError(
            f"payload_len {payload_len} exceeds max frame size {max_frame_bytes}"
        )
    payload = await reader.readexactly(payload_len) if payload_len else b""
    return Frame(opcode, flags, req_id, payload)


# --- float32 helpers (numpy imported lazily: the lint-job conformance
# --- and fuzz gates exercise the codec core with stdlib only) ---------


def encode_f32(arr) -> bytes:
    """ndarray -> contiguous little-endian float32 bytes."""
    import numpy as np

    return np.ascontiguousarray(arr, dtype="<f4").tobytes()


def decode_f32(data, shape: tuple[int, ...]):
    """bytes/memoryview -> float32 ndarray *view* of ``data`` (zero copy).

    Validates the element count against ``shape`` before reshaping so a
    lying meta header turns into a WireProtocolError, not a numpy crash.
    """
    import numpy as np

    if len(data) % 4:
        raise WireProtocolError(
            f"payload length {len(data)} is not a multiple of float32 size"
        )
    arr = np.frombuffer(data, dtype="<f4")
    expected = 1
    for dim in shape:
        if dim < 0:
            raise WireProtocolError(f"negative dimension in shape {shape}")
        expected *= dim
    if arr.size != expected:
        raise WireProtocolError(
            f"payload carries {arr.size} float32 values, shape {shape} "
            f"needs {expected}"
        )
    return arr.reshape(shape)

"""Device-claim registry: per-worker Placement shards must be DISJOINT.

PR 5's multi-worker front shards devices across workers only by
convention (each worker's factory builds its own Placement); nothing
stopped two workers from jitting their pool blocks onto the same device
and silently halving throughput.  This registry makes the convention a
checked invariant: each worker writes an atomic claim file naming the
devices it owns, and claiming a device already held by a LIVE other
worker fails loudly, naming the conflicting owner and devices.

Layout: ``<dir>/claims/<owner>.json`` with ``{"owner", "pid", "devices",
"claimed_at"}``.  Claims from dead pids are stale and reaped on the next
conflicting claim — a SIGKILLed worker cannot wedge its replacement.
No jax imports: the supervisor validates before any worker boots.
"""
from __future__ import annotations

import errno
import json
import os
import time
from pathlib import Path
from typing import Iterable, Mapping, Optional, Sequence


class DeviceClaimError(RuntimeError):
    """Two owners claim the same device(s) — the error message names the
    conflicting owner, its pid, and the overlapping devices."""


def _norm_devices(devices: Iterable) -> tuple[str, ...]:
    """Canonical device names: ints become ``"device:<i>"`` so mixed
    int/str specs of the same device collide as they should."""
    out = []
    for d in devices:
        name = f"device:{d}" if isinstance(d, int) else str(d)
        out.append(name)
    if len(set(out)) != len(out):
        raise DeviceClaimError(f"claim lists a device twice: {sorted(out)}")
    return tuple(sorted(out))


def _pid_alive(pid: int) -> bool:
    try:
        os.kill(pid, 0)
    except OSError as e:
        return e.errno == errno.EPERM  # alive but not ours
    return True


def validate_disjoint(claims: Mapping[str, Sequence]) -> None:
    """Pure check used by the supervisor BEFORE spawning: every pair of
    owners in ``claims`` must claim disjoint device sets."""
    seen: dict[str, str] = {}
    for owner, devices in claims.items():
        for dev in _norm_devices(devices):
            if dev in seen:
                raise DeviceClaimError(
                    f"device claim overlap: {owner!r} and {seen[dev]!r} "
                    f"both claim {dev}"
                )
            seen[dev] = owner


class DeviceClaimRegistry:
    """File-backed claims under ``<directory>/claims/``."""

    def __init__(self, directory: str | Path):
        self.directory = Path(directory) / "claims"
        self.directory.mkdir(parents=True, exist_ok=True)

    def _path(self, owner: str) -> Path:
        safe = "".join(c if (c.isalnum() or c in "-_.") else "_" for c in owner)
        return self.directory / f"{safe}.json"

    def claims(self) -> dict[str, dict]:
        out = {}
        for p in sorted(self.directory.glob("*.json")):
            try:
                entry = json.loads(p.read_text())
                out[entry["owner"]] = entry
            except (ValueError, KeyError):
                continue  # torn write of a crashed claimer; rename is atomic
        return out

    def claim(self, owner: str, devices: Sequence, *,
              pid: Optional[int] = None) -> dict:
        """Atomically claim ``devices`` for ``owner``.  Re-claiming by the
        same owner (a respawn) replaces its own entry.  A conflict with a
        live owner raises :class:`DeviceClaimError`; conflicts with dead
        owners reap the stale file and proceed."""
        pid = os.getpid() if pid is None else int(pid)
        devices = _norm_devices(devices)
        for other, entry in self.claims().items():
            if other == owner:
                continue
            overlap = sorted(set(devices) & set(entry.get("devices", ())))
            if not overlap:
                continue
            other_pid = int(entry.get("pid", -1))
            if other_pid > 0 and _pid_alive(other_pid):
                raise DeviceClaimError(
                    f"worker {owner!r} (pid {pid}) cannot claim "
                    f"{', '.join(overlap)}: already claimed by live worker "
                    f"{other!r} (pid {other_pid})"
                )
            self._path(other).unlink(missing_ok=True)  # stale: owner is dead
        entry = {
            "owner": owner,
            "pid": pid,
            "devices": list(devices),
            "claimed_at": time.time(),
        }
        tmp = self._path(owner).with_suffix(".json.tmp")
        tmp.write_text(json.dumps(entry, indent=1))
        os.replace(tmp, self._path(owner))
        return entry

    def release(self, owner: str) -> None:
        self._path(owner).unlink(missing_ok=True)

    def validate(self) -> dict[str, dict]:
        """Re-check every registered claim pair; returns the claim map."""
        entries = self.claims()
        validate_disjoint({o: e.get("devices", ()) for o, e in entries.items()})
        return entries

"""Slot-indexed session pool: thousands of logical streams, one program.

The paper keeps its datapath fed by batching independent work into the
same hardware pipeline; the serving-layer analogue is a fixed block of
``capacity`` stream slots — stacked per-layer (h, c) plus running error
sums — stepped by ONE compiled masked program regardless of which logical
streams are resident.  Admission/eviction only touches host-side slot
maps and zeroes the slot's state rows, so stream churn never retraces.

Under a sharded :class:`~repro.engine.placement.Placement` the slot block
itself distributes over the data mesh axis — contiguous row blocks of
``slots_per_device`` slots per device — so capacity scales to
``slots_per_device x mesh_size`` instead of what one device holds.  The
masked step is jitted with explicit in/out shardings (state in, state out
keep the row layout; params replicate), admission balances new streams
onto the least-loaded device, and per-device occupancy is gauged as
``pool.device_active`` so mesh imbalance is observable.  The single
placement is a strict no-op: programs, values and telemetry are unchanged.

Semantics contract (equivalence-tested in tests/test_gateway.py and, for
the sharded layout, tests/test_placement.py): a stream admitted to a slot
and stepped through any interleaving of pool steps observes exactly the
per-timestep running errors it would see alone through
``AnomalyService.stream_step`` — batch rows are independent through the
LSTM cell, and unmasked slots carry their state unchanged.
"""
from __future__ import annotations

from typing import Hashable, Mapping, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.engine.base import Engine
from repro.gateway.telemetry import Telemetry


class PoolFullError(RuntimeError):
    """Admission rejected: every slot is occupied (the gateway's
    fixed-capacity admission contract — callers shed or retry)."""


class UnknownStreamError(KeyError):
    """A stream id that is not resident in the pool."""


class SessionPool:
    """Fixed-capacity pooled streaming over one :class:`Engine`.

    >>> pool = SessionPool(engine, capacity=32)
    >>> pool.admit("conn-7")
    >>> errors = pool.step({"conn-7": x_t})   # any subset of residents
    >>> final = pool.evict("conn-7")
    """

    def __init__(
        self,
        engine: Engine,
        capacity: int,
        telemetry: Optional[Telemetry] = None,
    ):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.engine = engine
        self.capacity = capacity
        self.features = engine.cfg.lstm_ae.input_features
        self.telemetry = telemetry or Telemetry()
        # the pool always lays its block out on the ENGINE's placement —
        # the masked-step programs and the slot state must agree on one
        # layout (re-place via Engine.with_placement, not a pool knob)
        self.placement = engine.placement
        # the state block pads up to a per-device multiple; the padding rows
        # are never admitted (logical capacity stays exactly ``capacity``)
        self._block = self.placement.pad_rows(capacity)
        self.slots_per_device = self._block // self.placement.data_shards

        self._state = engine.init_stream_state(self._block)
        self._sq_sum = jnp.zeros((self._block,), jnp.float32)
        self._steps = jnp.zeros((self._block,), jnp.int32)
        self._slot_of: dict[Hashable, int] = {}
        # per-device free stacks + active counters: admission picks the
        # least-loaded device in O(devices), pops its stack in O(1) —
        # churn-heavy serving must not walk the resident map per admit.
        # Stacks hold only logical slots (< capacity); descending order so
        # pop() yields the lowest slot id first, matching the PR-2 order
        # bit for bit on a single device.
        self._free_count = capacity
        self._free_by_dev: list[list[int]] = [
            [] for _ in range(self.placement.data_shards)
        ]
        for slot in range(capacity - 1, -1, -1):
            self._free_by_dev[slot // self.slots_per_device].append(slot)
        self._active_by_dev = [0] * self.placement.data_shards

        def _pool_step(params, x, state, mask, sq_sum, steps):
            # one fused program: masked cell step + masked error accumulate
            y_t, state = engine._masked_stream_step(params, x, state, mask)
            sq = jnp.mean(
                jnp.square(y_t.astype(jnp.float32) - x.astype(jnp.float32)),
                axis=-1,
            )
            sq_sum = sq_sum + jnp.where(mask, sq, 0.0)
            steps = steps + mask.astype(jnp.int32)
            return state, sq_sum, steps

        def _clear_slot(state, sq_sum, steps, slot):
            state = jax.tree.map(lambda leaf: leaf.at[slot].set(0.0), state)
            return state, sq_sum.at[slot].set(0.0), steps.at[slot].set(0)

        def _load_slot(state, sq_sum, steps, slot, row, sq, n):
            # inverse of _clear_slot: write one stream's saved rows back
            # into its slot (the durability restore path)
            state = jax.tree.map(
                lambda leaf, r: leaf.at[slot].set(r.astype(leaf.dtype)),
                state, row,
            )
            return state, sq_sum.at[slot].set(sq), steps.at[slot].set(n)

        use_jit = engine.engine_cfg.jit
        if use_jit and self.placement.is_sharded:
            # slot rows live distributed over the data mesh: the fused step
            # is compiled with explicit shardings (state in == state out, so
            # the block never gathers between steps) and the initial block
            # is placed shard-by-shard up front
            rows = self.placement.row_sharding()
            repl = self.placement.replicated_sharding()
            self._pool_step = jax.jit(
                _pool_step,
                in_shardings=(repl, rows, rows, rows, rows, rows),
                out_shardings=(rows, rows, rows),
            )
            self._clear_slot = jax.jit(
                _clear_slot,
                in_shardings=(rows, rows, rows, repl),
                out_shardings=(rows, rows, rows),
            )
            self._load_slot = jax.jit(
                _load_slot,
                in_shardings=(rows, rows, rows, repl, repl, repl, repl),
                out_shardings=(rows, rows, rows),
            )
            self._state = jax.device_put(self._state, rows)
            self._sq_sum = jax.device_put(self._sq_sum, rows)
            self._steps = jax.device_put(self._steps, rows)
        else:
            self._pool_step = jax.jit(_pool_step) if use_jit else _pool_step
            self._clear_slot = jax.jit(_clear_slot) if use_jit else _clear_slot
            self._load_slot = jax.jit(_load_slot) if use_jit else _load_slot

    # -- membership -------------------------------------------------------

    @property
    def active(self) -> int:
        return len(self._slot_of)

    @property
    def resident(self) -> tuple:
        return tuple(self._slot_of)

    def device_of_slot(self, slot: int) -> int:
        """Which data shard holds ``slot`` (contiguous row blocks)."""
        return slot // self.slots_per_device

    def per_device_active(self) -> list:
        """Resident stream count per data shard — the mesh-imbalance view
        (a single-entry list under the single placement)."""
        return list(self._active_by_dev)

    def _pick_slot(self) -> int:
        """Pop a free slot from the least-loaded device that has one (ties
        broken by device order, deterministically), so resident streams
        spread across the mesh.  O(devices) + an O(1) stack pop; on a
        single device this is the original lowest-slot-first order bit for
        bit."""
        dev = min(
            (d for d, stack in enumerate(self._free_by_dev) if stack),
            key=lambda d: (self._active_by_dev[d], d),
        )
        self._free_count -= 1
        self._active_by_dev[dev] += 1
        return self._free_by_dev[dev].pop()

    def admit(self, stream_id: Hashable) -> int:
        """Claim a slot for ``stream_id`` (zeroed state); raises
        :class:`PoolFullError` when no slot is free."""
        if stream_id in self._slot_of:
            raise ValueError(f"stream {stream_id!r} is already resident")
        if not self._free_count:
            self.telemetry.count("pool.rejected")
            raise PoolFullError(
                f"pool at capacity ({self.capacity}); evict a stream first"
            )
        slot = self._pick_slot()
        self._slot_of[stream_id] = slot
        self._zero(slot)
        self.telemetry.count("pool.admitted")
        self._gauge_occupancy()
        return slot

    def evict(self, stream_id: Hashable) -> float:
        """Release the stream's slot; returns its final running error."""
        slot = self._require(stream_id)
        final = float(self.errors()[slot])
        del self._slot_of[stream_id]
        dev = self.device_of_slot(slot)
        self._free_by_dev[dev].append(slot)
        self._free_count += 1
        self._active_by_dev[dev] -= 1
        self.telemetry.count("pool.evicted")
        self._gauge_occupancy()
        return final

    def _gauge_occupancy(self) -> None:
        self.telemetry.gauge("pool.active", self.active)
        self.telemetry.gauge("pool.occupancy", self.active / self.capacity)
        if self.placement.is_sharded:
            self.telemetry.gauge_vec("pool.device_active", self.per_device_active())

    def reset(self, stream_id: Hashable) -> None:
        """Zero a resident stream's state and error counters in place."""
        self._zero(self._require(stream_id))

    def _require(self, stream_id: Hashable) -> int:
        try:
            return self._slot_of[stream_id]
        except KeyError:
            raise UnknownStreamError(
                f"stream {stream_id!r} is not resident (admit it first)"
            ) from None

    def _zero(self, slot: int) -> None:
        self._state, self._sq_sum, self._steps = self._clear_slot(
            self._state, self._sq_sum, self._steps, slot
        )

    # -- stepping ---------------------------------------------------------

    def step(self, inputs: Mapping[Hashable, "np.ndarray"]) -> dict:
        """Advance every stream in ``inputs`` one timestep.

        ``inputs`` maps resident stream ids to their next sample ``(F,)``;
        any subset of residents may step (the rest carry unchanged).
        Returns {stream_id: running mean error so far} for stepped streams.
        """
        if not inputs:
            return {}
        t0 = self.telemetry.now()
        slots = [self._require(sid) for sid in inputs]
        x = np.zeros((self._block, self.features), np.float32)
        mask = np.zeros((self._block,), bool)
        for sid, slot in zip(inputs, slots):
            sample = np.asarray(inputs[sid], np.float32)
            if sample.shape != (self.features,):
                raise ValueError(
                    f"stream {sid!r}: expected sample shape ({self.features},), "
                    f"got {sample.shape}"
                )
            x[slot] = sample
            mask[slot] = True
        self._state, self._sq_sum, self._steps = self._pool_step(
            self.engine._require_params(), jnp.asarray(x), self._state,
            jnp.asarray(mask), self._sq_sum, self._steps,
        )
        self.telemetry.record_pool_step(len(slots), self.capacity)
        errs = np.asarray(self.errors())
        # errs forced the device round-trip, so this wall time covers the
        # full assemble + compiled-step + readback path of one pool step
        self.telemetry.observe_stage(
            "pool_step_ms", (self.telemetry.now() - t0) * 1e3
        )
        return {sid: float(errs[slot]) for sid, slot in zip(inputs, slots)}

    # -- durability export / restore --------------------------------------
    #
    # Snapshots read a HOST COPY of the whole block; restores write one
    # slot's rows through a jitted setter (the mirror of ``_clear_slot``).
    # Rows travel as plain numpy in tree-leaves order so they serialize
    # through checkpoint/manager.py without carrying treedefs around.

    def slot_of(self, stream_id: Hashable) -> int:
        """Resident slot index of ``stream_id`` (UnknownStreamError if not)."""
        return self._require(stream_id)

    def export_block(self) -> tuple[list, np.ndarray, np.ndarray]:
        """Host copy of the full slot block: (state leaves in tree-leaves
        order, each ``(block, ...)``; sq_sum ``(block,)``; steps ``(block,)``).
        This is the snapshot read — it blocks only for device->host copies,
        never for host-side serialization."""
        leaves = [np.asarray(l) for l in jax.tree_util.tree_leaves(self._state)]
        return leaves, np.asarray(self._sq_sum), np.asarray(self._steps)

    def export_slot(self, stream_id: Hashable) -> tuple[list, float, int]:
        """Host copy of ONE stream's rows (state leaf rows in tree-leaves
        order, sq_sum, steps) — the park-on-disconnect path."""
        slot = self._require(stream_id)
        rows = [np.asarray(l[slot]) for l in jax.tree_util.tree_leaves(self._state)]
        return rows, float(self._sq_sum[slot]), int(self._steps[slot])

    def restore(self, stream_id: Hashable, rows, sq_sum: float,
                steps: int) -> int:
        """Admit ``stream_id`` into a free slot and load previously exported
        state rows + error counters into it.  ``rows`` is a sequence of
        per-leaf arrays in tree-leaves order (as produced by
        :meth:`export_slot` / a sliced :meth:`export_block`)."""
        treedef = jax.tree_util.tree_structure(self._state)
        expect = [l.shape[1:] for l in jax.tree_util.tree_leaves(self._state)]
        rows = [np.asarray(r) for r in rows]
        got = [r.shape for r in rows]
        if got != expect:
            raise ValueError(
                f"restore rows for {stream_id!r} do not match this pool's "
                f"state layout: got {got}, expected {expect} (arch mismatch?)"
            )
        row_tree = jax.tree_util.tree_unflatten(
            treedef, [jnp.asarray(r) for r in rows]
        )
        slot = self.admit(stream_id)
        self._state, self._sq_sum, self._steps = self._load_slot(
            self._state, self._sq_sum, self._steps, slot, row_tree,
            jnp.float32(sq_sum), jnp.int32(steps),
        )
        self.telemetry.count("pool.restored")
        return slot

    def errors(self) -> jnp.ndarray:
        """Running mean error per slot (capacity,) — lazy device array."""
        return self._sq_sum / jnp.maximum(self._steps, 1).astype(jnp.float32)

    def error_of(self, stream_id: Hashable) -> float:
        return float(self.errors()[self._require(stream_id)])

    def __repr__(self) -> str:
        pl = (f", placement={self.placement!r}"
              if self.placement.is_sharded else "")
        return (f"SessionPool(capacity={self.capacity}, active={self.active}, "
                f"schedule={self.engine.schedule.tag}{pl})")

"""Gateway telemetry: counters, gauges, and latency percentiles.

The software analogue of the paper's utilization discussion (Table 1):
whether the datapath stays fed is visible as *batch-fill ratio* (how much
of each flushed micro-batch was real work vs padding) and *pool
occupancy* (active slots / capacity).  Everything is plain host-side
bookkeeping — one `Telemetry` instance is shared by the session pool and
the micro-batching queue and surfaced via ``gateway.stats()``.

Single-threaded by design (the gateway is caller-driven); ``clock`` is
injectable so tests control time.
"""
from __future__ import annotations

import time
from collections import defaultdict, deque
from typing import Callable, Optional


def percentile(sorted_vals: list, p: float) -> float:
    """Nearest-rank percentile of an ascending list (0 when empty)."""
    if not sorted_vals:
        return 0.0
    idx = min(len(sorted_vals) - 1, max(0, int(round(p / 100.0 * (len(sorted_vals) - 1)))))
    return float(sorted_vals[idx])


class Telemetry:
    """Counters + gauges + a bounded latency window.

    counters  monotonically increasing event counts (requests, batches,
              stream-steps, rejections)
    gauges    last-set values (queue depth, pool occupancy)
    latency   ring buffer of per-request ms latencies -> p50/p95
    """

    def __init__(
        self,
        clock: Callable[[], float] = time.monotonic,
        latency_window: int = 4096,
    ):
        self._clock = clock
        self.counters: dict[str, float] = defaultdict(float)
        self.gauges: dict[str, float] = {}
        self._latency_ms: deque = deque(maxlen=latency_window)
        self._t0: Optional[float] = None

    # -- recording --------------------------------------------------------

    def _touch(self) -> float:
        now = self._clock()
        if self._t0 is None:
            self._t0 = now
        return now

    def count(self, name: str, n: float = 1) -> None:
        self._touch()
        self.counters[name] += n

    def gauge(self, name: str, value: float) -> None:
        self._touch()
        self.gauges[name] = float(value)

    def gauge_vec(self, name: str, values) -> None:
        """A per-device gauge vector (e.g. slot occupancy or flush fill per
        mesh shard) — stored as a tuple so ``stats()`` serialises it as a
        JSON list and mesh imbalance is observable over the wire."""
        self._touch()
        self.gauges[name] = tuple(float(v) for v in values)

    def observe_latency_ms(self, ms: float) -> None:
        self._touch()
        self._latency_ms.append(float(ms))

    def reset(self) -> None:
        """Zero all counters/gauges/latency history (and the uptime
        epoch).  For drawing the line after warm-up traffic — compile
        warming must not inflate served-request counters or fill
        ratios."""
        self.counters.clear()
        self.gauges.clear()
        self._latency_ms.clear()
        self._t0 = None

    def record_batch(self, filled: int, slots: int, wait_ms: float = 0.0) -> None:
        """One micro-batch flush: ``filled`` real requests in ``slots``
        padded lanes (fill ratio = filled/slots aggregated over flushes)."""
        self.count("batch.flushes")
        self.count("batch.filled", filled)
        self.count("batch.slots", slots)
        self.count("batch.wait_ms", wait_ms)

    def record_pool_step(self, active: int, capacity: int) -> None:
        """One pooled streaming step advancing ``active`` of ``capacity``
        slots.  Gauges the stepped fraction as ``pool.step_fill`` (the
        per-step analogue of datapath utilization); ``pool.occupancy``
        (resident slots / capacity) is gauged by the pool on admit/evict."""
        self.count("pool.steps")
        self.count("pool.stream_steps", active)
        self.gauge("pool.step_fill", active / max(1, capacity))

    # -- reading ----------------------------------------------------------

    def latency_percentile(self, p: float) -> float:
        return percentile(sorted(self._latency_ms), p)

    @property
    def uptime_s(self) -> float:
        if self._t0 is None:
            return 0.0
        return max(self._clock() - self._t0, 1e-9)

    def stats(self) -> dict:
        c = self.counters
        flushes = c.get("batch.flushes", 0.0)
        slots = c.get("batch.slots", 0.0)
        steps = c.get("pool.stream_steps", 0.0)
        lat = sorted(self._latency_ms)
        up = self.uptime_s
        return {
            "uptime_s": up,
            "counters": dict(c),
            "gauges": dict(self.gauges),
            "batch_fill_ratio": (c.get("batch.filled", 0.0) / slots) if slots else 0.0,
            "mean_batch_wait_ms": (c.get("batch.wait_ms", 0.0) / flushes) if flushes else 0.0,
            "latency_ms": {
                "count": len(lat),
                "p50": percentile(lat, 50),
                "p95": percentile(lat, 95),
            },
            "requests_per_s": c.get("queue.completed", 0.0) / up if up else 0.0,
            "stream_steps_per_s": steps / up if up else 0.0,
        }

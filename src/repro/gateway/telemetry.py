"""Gateway telemetry: counters, gauges, and mergeable latency histograms.

The software analogue of the paper's utilization discussion (Table 1):
whether the datapath stays fed is visible as *batch-fill ratio* (how much
of each flushed micro-batch was real work vs padding) and *pool
occupancy* (active slots / capacity).  Everything is plain host-side
bookkeeping — one `Telemetry` instance is shared by the session pool and
the micro-batching queue and surfaced via ``gateway.stats()``.

Latency lives in fixed-boundary log-linear histograms
(:class:`repro.obs.histogram.Histogram`) instead of a raw sample ring:
per-worker histograms serialize through ``stats()`` as sparse bucket
dicts and SUM exactly across workers, so a multi-worker front reports
true front-wide percentiles.  Besides the request-latency histogram
(``request_ms``) there are per-stage histograms (``queue_wait_ms``,
``batch_wait_ms``, ``assemble_ms``, ``compute_ms``, ``wire_ms``,
``pool_step_ms``) decomposing where wire latency goes; stage recording
is gated by ``detail`` so the overhead benchmark can price it.

Scalar gauges and vector gauges (per-mesh-shard values) live in separate
maps — ``gauges`` is honestly ``dict[str, float]`` and ``gauge_vecs``
holds the tuples — and the uptime epoch is explicit: set at
construction and on every ``reset()``, so ``stats()`` rates are
well-defined from the first post-reset event instead of being inflated
until the window fills.

Single-threaded by design (the gateway is caller-driven); ``clock`` is
injectable so tests control time.
"""
from __future__ import annotations

import time
from collections import defaultdict
from typing import Callable, Iterable, Tuple

from repro.obs.histogram import Histogram

# the request-latency histogram's key in ``Telemetry.histograms``
REQUEST_HIST = "request_ms"

# counters whose short-horizon rates feed the control plane (sliding
# window, not lifetime averages — see _RateWindow)
_WINDOWED_COUNTERS = ("queue.submitted", "queue.completed")


class _RateWindow:
    """Sliding-window event rate from a ring of per-interval counters.

    Lifetime rates (``count / uptime``) answer "how busy has this process
    been since boot" — useless to a controller that must react to the
    arrival rate *now*.  This ring holds one counter per fixed interval;
    ``add`` credits the interval containing ``now`` (zeroing any
    intervals skipped since the last event) and ``rate`` divides the
    ring's sum by the window span, clipped to the time actually elapsed
    since construction so the estimate is unbiased while the ring is
    still filling.
    """

    __slots__ = ("interval_s", "intervals", "_counts", "_last_idx", "_t_start")

    def __init__(self, t_start: float, window_s: float = 10.0, intervals: int = 20):
        if window_s <= 0 or intervals < 1:
            raise ValueError("window_s must be > 0 and intervals >= 1")
        self.interval_s = window_s / intervals
        self.intervals = intervals
        self._counts = [0.0] * intervals
        self._last_idx = int(t_start / self.interval_s)
        self._t_start = t_start

    @property
    def window_s(self) -> float:
        return self.interval_s * self.intervals

    def _advance(self, now: float) -> int:
        idx = int(now / self.interval_s)
        if idx > self._last_idx:
            # zero every interval skipped since the last event; a gap
            # longer than the ring clears it entirely
            for i in range(self._last_idx + 1,
                           min(idx, self._last_idx + self.intervals) + 1):
                self._counts[i % self.intervals] = 0.0
            self._last_idx = idx
        return idx

    def add(self, now: float, n: float = 1.0) -> None:
        idx = self._advance(now)
        self._counts[idx % self.intervals] += n

    def rate(self, now: float) -> float:
        """Events per second over the trailing window (clipped to the
        elapsed time while the ring is younger than one full window)."""
        self._advance(now)
        span = min(self.window_s, max(now - self._t_start, self.interval_s))
        return sum(self._counts) / span


def percentile(sorted_vals: list, p: float) -> float:
    """Nearest-rank percentile of an ascending list (0 when empty)."""
    if not sorted_vals:
        return 0.0
    idx = min(len(sorted_vals) - 1, max(0, int(round(p / 100.0 * (len(sorted_vals) - 1)))))
    return float(sorted_vals[idx])


class Telemetry:
    """Counters + gauges + fixed-boundary latency histograms.

    counters    monotonically increasing event counts (requests, batches,
                stream-steps, rejections; per-protocol transport traffic
                as ``wire.req_json`` / ``wire.req_bp1`` and per-connection
                ``wire.conn_json`` / ``wire.conn_bp1`` — how much of a
                front's load negotiated the binary protocol)
    gauges      last-set scalar values (queue depth, pool occupancy)
    gauge_vecs  last-set per-shard vectors (device occupancy / flush fill)
    histograms  request latency + per-stage decompositions -> p50/p95/p99
    """

    def __init__(
        self,
        clock: Callable[[], float] = time.monotonic,
        detail: bool = True,
        rate_window_s: float = 10.0,
    ):
        self._clock = clock
        self.detail = bool(detail)
        self.counters: dict[str, float] = defaultdict(float)
        self.gauges: dict[str, float] = {}
        self.gauge_vecs: dict[str, Tuple[float, ...]] = {}
        self.histograms: dict[str, Histogram] = {}
        # explicit uptime epoch: rates are well-defined immediately, and
        # reset() re-arms it (no lazy first-event initialization)
        self._t0: float = clock()
        self._rate_window_s = float(rate_window_s)
        self._windows: dict[str, _RateWindow] = {
            name: _RateWindow(self._t0, self._rate_window_s)
            for name in _WINDOWED_COUNTERS
        }

    # -- recording --------------------------------------------------------

    def now(self) -> float:
        """The telemetry clock (injectable) — shared by instrumented call
        sites so stage timings and uptime agree on one time source."""
        return self._clock()

    def count(self, name: str, n: float = 1) -> None:
        self.counters[name] += n
        win = self._windows.get(name)
        if win is not None:
            win.add(self._clock(), n)

    def gauge(self, name: str, value: float) -> None:
        self.gauges[name] = float(value)

    def gauge_vec(self, name: str, values: Iterable[float]) -> None:
        """A per-device gauge vector (e.g. slot occupancy or flush fill
        per mesh shard) — kept out of ``gauges`` so that map stays
        ``dict[str, float]``; ``stats()`` serialises vectors as JSON
        lists under ``gauge_vecs``."""
        self.gauge_vecs[name] = tuple(float(v) for v in values)

    def observe(self, name: str, ms: float) -> None:
        """Record one duration into the named histogram."""
        hist = self.histograms.get(name)
        if hist is None:
            hist = self.histograms[name] = Histogram()
        hist.record(float(ms))

    def observe_stage(self, name: str, ms: float) -> None:
        """Per-stage histogram sample; dropped when ``detail`` is off (the
        obs_overhead benchmark's 'off' arm)."""
        if self.detail:
            self.observe(name, ms)

    def observe_latency_ms(self, ms: float) -> None:
        self.observe(REQUEST_HIST, ms)

    def reset(self) -> None:
        """Zero all counters/gauges/histograms and re-arm the uptime
        epoch.  For drawing the line after warm-up traffic — compile
        warming must not inflate served-request counters or fill
        ratios — and rates are well-defined from the very next event."""
        self.counters.clear()
        self.gauges.clear()
        self.gauge_vecs.clear()
        self.histograms.clear()
        self._t0 = self._clock()
        self._windows = {
            name: _RateWindow(self._t0, self._rate_window_s)
            for name in _WINDOWED_COUNTERS
        }

    def record_batch(self, filled: int, slots: int, wait_ms: float = 0.0) -> None:
        """One micro-batch flush: ``filled`` real requests in ``slots``
        padded lanes (fill ratio = filled/slots aggregated over flushes)."""
        self.count("batch.flushes")
        self.count("batch.filled", filled)
        self.count("batch.slots", slots)
        self.count("batch.wait_ms", wait_ms)
        self.observe_stage("batch_wait_ms", wait_ms)

    def record_pool_step(self, active: int, capacity: int) -> None:
        """One pooled streaming step advancing ``active`` of ``capacity``
        slots.  Gauges the stepped fraction as ``pool.step_fill`` (the
        per-step analogue of datapath utilization); ``pool.occupancy``
        (resident slots / capacity) is gauged by the pool on admit/evict."""
        self.count("pool.steps")
        self.count("pool.stream_steps", active)
        self.gauge("pool.step_fill", active / max(1, capacity))

    # -- reading ----------------------------------------------------------

    @property
    def request_histogram(self) -> Histogram:
        hist = self.histograms.get(REQUEST_HIST)
        if hist is None:
            hist = self.histograms[REQUEST_HIST] = Histogram()
        return hist

    def latency_percentile(self, p: float) -> float:
        return self.request_histogram.percentile(p)

    @property
    def uptime_s(self) -> float:
        return max(self._clock() - self._t0, 1e-9)

    def windowed_rate(self, name: str) -> float:
        """Sliding-window rate (events/s) for a windowed counter; 0.0 for
        counters outside ``_WINDOWED_COUNTERS``."""
        win = self._windows.get(name)
        return win.rate(self._clock()) if win is not None else 0.0

    def stats(self) -> dict:
        c = self.counters
        flushes = c.get("batch.flushes", 0.0)
        slots = c.get("batch.slots", 0.0)
        steps = c.get("pool.stream_steps", 0.0)
        req = self.request_histogram
        up = self.uptime_s
        return {
            "uptime_s": up,
            "counters": dict(c),
            "gauges": dict(self.gauges),
            "gauge_vecs": {k: list(v) for k, v in self.gauge_vecs.items()},
            "batch_fill_ratio": (c.get("batch.filled", 0.0) / slots) if slots else 0.0,
            "mean_batch_wait_ms": (c.get("batch.wait_ms", 0.0) / flushes) if flushes else 0.0,
            "latency_ms": {
                "count": req.count,
                "p50": req.percentile(50),
                "p95": req.percentile(95),
                "p99": req.percentile(99),
                "sum_ms": req.sum,
                "buckets": {str(i): n for i, n in sorted(req.counts.items())},
            },
            "histograms": {k: h.to_dict() for k, h in self.histograms.items()},
            "requests_per_s": c.get("queue.completed", 0.0) / up,
            "stream_steps_per_s": steps / up,
            # windowed (short-horizon) rates — what the control plane
            # actuates on; the two keys above are lifetime averages
            "arrival_rps_window": self.windowed_rate("queue.submitted"),
            "completed_rps_window": self.windowed_rate("queue.completed"),
        }

"""Synchronous client for :class:`~repro.gateway.server.GatewayServer`.

Stdlib-socket counterpart of the wire protocols documented in
``server.py`` and :mod:`repro.gateway.wire` — used by the client
example, the transport tests, the smoke script and the transport
benchmarks.  One connection carries at most one streaming session (the
server maps connections to pool sessions) plus any number of in-flight
one-shot score requests.

Protocol negotiation — ``protocol="auto"`` (the default) opens the
connection with the 4-byte bp1 preamble: a bp1-capable server answers a
binary ``HELLO`` frame and the connection runs the binary protocol
(:attr:`protocol` becomes ``"bp1"``); a legacy JSON-lines server answers
a JSON error line instead, which the client consumes and silently falls
back to JSON on the same connection.  ``protocol="json"`` skips the
preamble entirely — the connection is byte-for-byte the PR 3 client —
and ``protocol="binary"`` raises if the server can't negotiate bp1.
Either way every public method below behaves identically; on bp1 the
hot ops (``submit``/``score``/``step``) travel as raw-float32 frames
(no float lists) and :meth:`score_many`/:meth:`step_many` additionally
pipeline many windows per frame.

Responses can arrive out of submission order (``score`` answers when the
server's micro-batcher flushes), so the client matches responses to
requests by ``id``: :meth:`submit` returns a request id immediately and
:meth:`collect` blocks until that id's response has been read, parking
any other responses it sees on the way.  On bp1 the id travels in the
frame header; pipelined frames complete out of order the same way.

Durability (server-side ``enable_durability``): ``step`` responses then
carry ``seq`` + a signed resumption ``token``, which the client tracks
(:attr:`session_token`) alongside a bounded replay buffer of its last
``replay_window`` samples.  After losing the connection — or the whole
worker — open a NEW client and call :meth:`resume` with the old client's
token/buffer: the server restores the session from its latest snapshot
and the client transparently re-steps the buffered samples past the
snapshot position, so scores continue exactly as if nothing died.
"""
from __future__ import annotations

import json
import socket
import time
from collections import OrderedDict
from typing import Optional, Sequence

import numpy as np

from repro.gateway import wire


class ReplayWindowExceededError(RuntimeError):
    """The server's snapshot is older than the oldest sample in the
    client's replay buffer — the gap cannot be replayed.  Raise the
    snapshot cadence or the client's ``replay_window``."""


class GatewayClientError(RuntimeError):
    """An ``ok: false`` response; ``.error`` holds the server-side
    exception name (e.g. ``"GatewayOverloadedError"``)."""

    def __init__(self, error: str, message: str):
        super().__init__(f"{error}: {message}")
        self.error = error
        self.message = message


class GatewayClient:
    """One connection to a running gateway server.

    >>> with GatewayClient(host, port) as client:
    ...     client.step(x_t)["running_error"]     # streaming session
    ...     client.end_session()["final"]
    ...     client.score(window)                  # one-shot (blocks on flush)
    ...     rids = [client.submit(w) for w in windows]   # concurrent
    ...     scores = [client.collect(r)["score"] for r in rids]
    """

    def __init__(self, host: str = "127.0.0.1", port: int = 0,
                 timeout: float = 30.0, replay_window: int = 256,
                 protocol: str = "auto"):
        if protocol not in ("auto", "binary", "json"):
            raise ValueError(
                f"protocol must be 'auto', 'binary' or 'json', got {protocol!r}"
            )
        self._sock = socket.create_connection((host, port), timeout=timeout)
        # request/response protocol: never let Nagle hold a small frame
        # back waiting for the previous one's ACK (the asyncio server side
        # already sets this on its transports)
        self._sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        self._rfile = self._sock.makefile("rb")
        self._next_id = 0
        self._parked: dict = {}  # id -> response that arrived out of order
        # durability bookkeeping: the freshest resumption token plus the
        # last `replay_window` (seq -> sample) pairs, enough to re-step
        # past any snapshot at most `replay_window` steps behind
        self.replay_window = int(replay_window)
        self._token: Optional[str] = None
        self._seq = 0
        self._replay: "OrderedDict[int, list]" = OrderedDict()
        #: Active wire protocol after negotiation: "bp1" or "json".
        self.protocol = "json"
        #: The server's HELLO meta when bp1 negotiated (version, limits).
        self.server_info: dict = {}
        if protocol != "json":
            self._negotiate(require=(protocol == "binary"))

    # -- wire --------------------------------------------------------------

    def _negotiate(self, require: bool) -> None:
        """Send the bp1 preamble and read the server's verdict: the first
        response byte is either the frame magic (bp1 negotiated — consume
        the HELLO frame) or ``{`` (a legacy server's JSON error line for
        the undecodable preamble — consume it and fall back to JSON)."""
        self._sock.sendall(wire.PREAMBLE)
        head = self._rfile.read(1)
        if not head:
            raise ConnectionError("server closed the connection while negotiating")
        if head == wire.MAGIC[:1]:
            opcode, flags, _rid, length = wire.unpack_header(
                head + self._read_exact(wire.HEADER_SIZE - 1)
            )
            meta, _ = wire.split_payload(self._read_exact(length))
            if opcode != wire.OP_HELLO or meta.get("version") != wire.VERSION:
                raise GatewayClientError(
                    "ProtocolError",
                    f"unexpected bp1 greeting: opcode 0x{opcode:02x}, meta {meta}",
                )
            self.protocol = "bp1"
            self.server_info = meta
            return
        line = head + self._rfile.readline()
        if require:
            raise GatewayClientError(
                "ProtocolError",
                f"server does not speak bp1 (answered {line[:80]!r})",
            )
        self.protocol = "json"

    def _send(self, payload: dict) -> int:
        rid = self._next_id
        self._next_id += 1
        payload["id"] = rid
        self._sock.sendall((json.dumps(payload) + "\n").encode())
        return rid

    def _send_frame(self, opcode: int, meta: Optional[dict] = None,
                    data: bytes = b"") -> int:
        rid = self._next_id
        self._next_id += 1
        self._sock.sendall(wire.pack_frame(opcode, rid, meta=meta, data=data))
        return rid

    def _read_exact(self, n: int) -> bytes:
        buf = self._rfile.read(n) if n else b""
        if len(buf) < n:
            raise ConnectionError("server closed the connection")
        return buf

    def _read_frame(self) -> dict:
        """Read one frame and normalize it into the same dict shape the
        JSON protocol produces, so everything above :meth:`collect` is
        protocol-agnostic: header req_id -> ``id``, meta -> fields, raw
        float32 data -> ``scores`` (score) / ``running_errors`` (step),
        plus the scalar ``score``/``alert`` aliases for single-window
        frames."""
        opcode, flags, rid, length = wire.unpack_header(
            self._read_exact(wire.HEADER_SIZE)
        )
        meta, data = wire.split_payload(self._read_exact(length))
        decoded = dict(meta)
        decoded["id"] = rid
        if flags & wire.FLAG_ERROR:
            decoded.setdefault("ok", False)
        else:
            decoded.setdefault("ok", True)
            decoded.setdefault("op", wire.NAME_BY_OPCODE.get(opcode))
            values = (np.frombuffer(data, "<f4").tolist() if len(data) else [])
            if opcode == wire.OP_SCORE:
                decoded["scores"] = values
                if len(values) == 1:
                    decoded["score"] = values[0]
                    if isinstance(decoded.get("alert"), list):
                        decoded["alert"] = decoded["alert"][0]
            elif opcode == wire.OP_STEP:
                decoded["running_errors"] = values
                if len(values) == 1 and isinstance(decoded.get("alert"), list):
                    decoded["alert"] = decoded["alert"][0]
        return decoded

    def _read_until(self, rid: int) -> dict:
        while rid not in self._parked:
            if self.protocol == "bp1":
                decoded = self._read_frame()
                got = decoded["id"]
                if got == wire.NO_REQUEST_ID and not decoded.get("ok"):
                    # connection-level failure (framing loss): the server
                    # answers on the sentinel id and hangs up
                    raise GatewayClientError(
                        decoded.get("error", "UnknownError"),
                        decoded.get("message", ""),
                    )
                self._parked[got] = decoded
                continue
            line = self._rfile.readline()
            if not line:
                raise ConnectionError("server closed the connection")
            resp = json.loads(line)
            if resp.get("id") is None and not resp.get("ok"):
                # connection-level failure (unparseable / over-long line):
                # the server answers without an id and hangs up — surface
                # its reason instead of a bare ConnectionError later
                raise GatewayClientError(
                    resp.get("error", "UnknownError"), resp.get("message", "")
                )
            self._parked[resp.get("id")] = resp
        return self._parked.pop(rid)

    def collect(self, rid: int) -> dict:
        """Block until request ``rid``'s response arrives; raises
        :class:`GatewayClientError` on ``ok: false``."""
        resp = self._read_until(rid)
        if not resp.get("ok"):
            raise GatewayClientError(
                resp.get("error", "UnknownError"), resp.get("message", "")
            )
        return resp

    def request(self, op: str, **fields) -> dict:
        """Send one request and wait for its response.  On bp1 the same
        dict travels as a generic meta frame (unknown ``op`` names get an
        unassigned opcode so the server still answers the error — JSON
        parity); ``score``/``step`` tunnel their float lists in meta,
        which works but skips the raw-float32 fast path — prefer
        :meth:`submit`/:meth:`step`."""
        if self.protocol == "bp1":
            opcode = wire.OPCODE_BY_NAME.get(op, 0x7F)
            return self.collect(self._send_frame(opcode, meta=fields or None))
        return self.collect(self._send({"op": op, **fields}))

    # -- streaming session -------------------------------------------------

    @property
    def session_token(self) -> Optional[str]:
        """The freshest resumption token (None before the first step, or
        on a server without durability)."""
        return self._token

    @property
    def session_seq(self) -> int:
        return self._seq

    def replay_buffer(self) -> list:
        """``(seq, sample)`` pairs this client could replay — hand these
        (with :attr:`session_token`) to a NEW client's :meth:`resume`
        when this one's connection/worker died."""
        return [(seq, list(x)) for seq, x in self._replay.items()]

    def _track(self, resp: dict, x: list) -> dict:
        if "token" in resp:
            self._token = resp["token"]
            self._seq = int(resp.get("seq", self._seq))
            if x is not None:
                self._replay[self._seq] = x
                while len(self._replay) > self.replay_window:
                    self._replay.popitem(last=False)
        return resp

    def step(self, x_t) -> dict:
        """Advance this connection's pool session one timestep; returns the
        response (``running_error`` and, when calibrated, ``alert``; with
        durability also ``seq`` + ``token``, tracked on the client)."""
        if self.protocol == "bp1":
            arr = np.ascontiguousarray(x_t, dtype="<f4")
            rid = self._send_frame(wire.OP_STEP, meta={"t": 1},
                                   data=arr.tobytes())
            return self._track(self.collect(rid), arr.tolist())
        x = np.asarray(x_t, np.float32).tolist()
        return self._track(self.request("step", x=x), x)

    def step_many(self, xs) -> list:
        """Advance the session ``len(xs)`` timesteps; returns every
        intermediate running error.  On bp1 all samples travel in ONE
        frame (one round-trip instead of ``len(xs)``); on JSON this
        degrades to a per-sample loop with identical results.  Durable
        sessions track the frame's token/seq against each sample's
        implied position, so :meth:`resume` replay stays exact."""
        if self.protocol != "bp1":
            return [float(self.step(x)["running_error"]) for x in xs]
        arr = np.ascontiguousarray(xs, dtype="<f4")
        if arr.ndim != 2:
            raise ValueError(f"expected (k, F) samples, got shape {arr.shape}")
        k = arr.shape[0]
        if k == 0:
            return []
        rid = self._send_frame(wire.OP_STEP, meta={"t": k}, data=arr.tobytes())
        decoded = self.collect(rid)
        errors = decoded.get("running_errors") or []
        if "token" in decoded:
            # the frame's seq/token cover its LAST sample; samples i of k
            # sit at seq (last - k + 1 + i) in the replay buffer
            self._token = decoded["token"]
            last = self._seq = int(decoded.get("seq", self._seq))
            for i in range(k):
                self._replay[last - k + 1 + i] = arr[i].tolist()
            while len(self._replay) > self.replay_window:
                self._replay.popitem(last=False)
        return [float(e) for e in errors]

    def end_session(self) -> dict:
        """Evict the session; returns the response (``final`` score).  On
        a durable server this CLOSES the session — its tokens stop
        resuming once old snapshots age out."""
        resp = self.request("close")
        self._token = None
        self._seq = 0
        self._replay.clear()
        return resp

    def resume(self, token: Optional[str] = None,
               replay: Optional[Sequence] = None) -> dict:
        """Revive a durable session on THIS connection from ``token``
        (default: this client's own last token — useful after a plain
        reconnect; pass the dead client's token/``replay_buffer()`` when
        migrating).  Replays buffered samples newer than the server's
        snapshot position, so the session continues exactly where the old
        connection stopped.  Returns ``{"seq": <position after replay>,
        "running_error": .., "replayed": <n>}``."""
        token = self._token if token is None else token
        if token is None:
            raise ValueError("no resumption token (pass one, or step first)")
        entries = (list(self._replay.items()) if replay is None
                   else [(int(s), list(x)) for s, x in replay])
        resp = self.request("resume", token=token)
        self._token = resp.get("token", token)
        base = self._seq = int(resp["seq"])
        todo = sorted((s, x) for s, x in entries if s > base)
        if todo:
            expect = list(range(base + 1, base + 1 + len(todo)))
            if [s for s, _ in todo] != expect:
                raise ReplayWindowExceededError(
                    f"snapshot is at seq {base} but the replay buffer "
                    f"covers {todo[0][0]}..{todo[-1][0]} — "
                    f"{todo[0][0] - base - 1} step(s) are unrecoverable"
                )
        self._replay = OrderedDict(
            (s, x) for s, x in entries if s <= base
        )
        out = dict(resp)
        for _, x in todo:
            out = self.step(x)
        return {
            "seq": self._seq,
            "running_error": out["running_error"],
            "replayed": len(todo),
            "alert": out.get("alert"),
        }

    # -- one-shot scoring --------------------------------------------------

    def submit(self, series, *, priority: Optional[int] = None,
               tenant: Optional[str] = None) -> int:
        """Fire a one-shot score request; returns its id for
        :meth:`collect` (responses arrive on the server's flush cadence).
        ``priority`` (0 = highest class) and ``tenant`` feed the server's
        admission controller when one is attached; both are omitted from
        the wire payload when None, so legacy traffic is byte-identical.
        On bp1 the window travels as one raw-float32 SCORE frame."""
        if self.protocol == "bp1":
            arr = np.ascontiguousarray(series, dtype="<f4")
            if arr.ndim != 2:
                raise ValueError(f"expected (T, F) window, got shape {arr.shape}")
            meta = {"n": 1, "t": int(arr.shape[0]), "f": int(arr.shape[1])}
            if priority is not None:
                meta["priority"] = int(priority)
            if tenant is not None:
                meta["tenant"] = str(tenant)
            return self._send_frame(wire.OP_SCORE, meta=meta, data=arr.tobytes())
        payload = {"op": "score",
                   "series": np.asarray(series, np.float32).tolist()}
        if priority is not None:
            payload["priority"] = int(priority)
        if tenant is not None:
            payload["tenant"] = str(tenant)
        return self._send(payload)

    def score(self, series, *, priority: Optional[int] = None,
              tenant: Optional[str] = None) -> float:
        """Submit one window and block for its score."""
        return float(self.collect(
            self.submit(series, priority=priority, tenant=tenant)
        )["score"])

    def traced_score(self, series) -> dict:
        """One-shot score carrying a trace id, returning the full span.

        The request's ``trace`` field opts the server into span capture
        (old servers simply ignore it — the field is additive); the
        response's ``trace.stages`` carries the server-side breakdown
        (``dispatch`` / ``queue_wait`` / ``assemble`` / ``compute``).
        Client-side this method measures ``serialize`` (ndarray -> JSON
        text) and attributes the end-to-end remainder to ``wire``
        (sockets + framing + readline), so the returned stages sum to the
        observed end-to-end wire latency.

        Returns ``{"score", "trace_id", "stages": {name: ms}, "e2e_ms",
        "server_ms", "alert"}``.
        """
        t0 = time.perf_counter()
        rid = self._next_id
        self._next_id += 1
        tid = f"c{rid:x}"
        if self.protocol == "bp1":
            arr = np.ascontiguousarray(series, dtype="<f4")
            buf = wire.pack_frame(
                wire.OP_SCORE, rid,
                meta={"n": 1, "t": int(arr.shape[0]), "f": int(arr.shape[1]),
                      "trace": tid},
                data=arr.tobytes(),
            )
        else:
            buf = (json.dumps({
                "op": "score", "id": rid, "trace": tid,
                "series": np.asarray(series, np.float32).tolist(),
            }) + "\n").encode()
        t_serialized = time.perf_counter()
        self._sock.sendall(buf)
        resp = self.collect(rid)
        e2e_ms = (time.perf_counter() - t0) * 1e3
        trace = resp.get("trace") or {}
        stages = {"serialize": (t_serialized - t0) * 1e3}
        stages.update(trace.get("stages") or {})
        # everything not attributed above is transit: kernel buffers,
        # framing, the reply's decode.  Clamped at 0 — server stages are
        # sub-intervals of the client's wait, so the remainder is
        # non-negative up to clock granularity.
        stages["wire"] = max(0.0, e2e_ms - sum(stages.values()))
        return {
            "score": float(resp["score"]),
            "trace_id": str(trace.get("id", tid)),
            "stages": stages,
            "e2e_ms": e2e_ms,
            "server_ms": trace.get("total_ms"),
            "alert": resp.get("alert"),
        }

    def score_many(self, windows: Sequence, *,
                   windows_per_frame: int = 64) -> list:
        """Submit every window up front (so the server can micro-batch
        them), then collect all scores in submission order.

        On bp1 this is the pipelined fast path: consecutive same-shape
        windows are packed ``windows_per_frame`` at a time into single
        SCORE frames (one header + one contiguous float32 block for the
        whole group), all frames are written before any response is
        read, and responses are matched by frame id — so the depth-1
        sweep of the ``gateway_binary`` benchmark is literally
        ``windows_per_frame=1``.  On JSON this degrades to the PR 3
        submit/collect loop with identical results."""
        if self.protocol != "bp1":
            rids = [self.submit(w) for w in windows]
            return [float(self.collect(rid)["score"]) for rid in rids]
        depth = int(windows_per_frame)
        if depth < 1:
            raise ValueError(f"windows_per_frame must be >= 1, got {depth}")
        arrs = [np.ascontiguousarray(w, dtype="<f4") for w in windows]
        for arr in arrs:
            if arr.ndim != 2:
                raise ValueError(
                    f"expected (T, F) windows, got shape {arr.shape}"
                )
        frames = []  # (rid, window count) in submission order
        i = 0
        while i < len(arrs):
            j = i + 1
            while (j < len(arrs) and j - i < depth
                   and arrs[j].shape == arrs[i].shape):
                j += 1
            chunk = arrs[i:j]
            t, f = chunk[0].shape
            data = (np.stack(chunk).tobytes() if len(chunk) > 1
                    else chunk[0].tobytes())
            rid = self._send_frame(
                wire.OP_SCORE,
                meta={"n": len(chunk), "t": int(t), "f": int(f)},
                data=data,
            )
            frames.append((rid, len(chunk)))
            i = j
        scores: list = []
        for rid, count in frames:
            decoded = self.collect(rid)
            got = decoded.get("scores") or []
            if len(got) != count:
                raise GatewayClientError(
                    "ProtocolError",
                    f"frame {rid} answered {len(got)} scores for {count} windows",
                )
            scores.extend(float(s) for s in got)
        return scores

    # -- control -----------------------------------------------------------

    def stats(self) -> dict:
        return self.request("stats")["stats"]

    def recalibrate(self, threshold: Optional[float]) -> dict:
        """Swap the server-side detection threshold live (None disables
        alerting); resident sessions keep serving."""
        return self.request("recalibrate", threshold=threshold)

    def ping(self) -> bool:
        return bool(self.request("ping")["ok"])

    # -- lifecycle ---------------------------------------------------------

    def close(self) -> None:
        try:
            self._rfile.close()
        finally:
            self._sock.close()

    def __enter__(self) -> "GatewayClient":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


__all__ = ["GatewayClient", "GatewayClientError", "ReplayWindowExceededError"]

"""Synchronous JSON-lines client for :class:`~repro.gateway.server.GatewayServer`.

Stdlib-socket counterpart of the wire protocol documented in
``server.py`` — used by the client example, the transport tests, the
smoke script and the ``gateway_transport`` benchmark.  One connection
carries at most one streaming session (the server maps connections to
pool sessions) plus any number of in-flight one-shot score requests.

Responses can arrive out of submission order (``score`` answers when the
server's micro-batcher flushes), so the client matches responses to
requests by ``id``: :meth:`submit` returns a request id immediately and
:meth:`collect` blocks until that id's response has been read, parking
any other responses it sees on the way.

Durability (server-side ``enable_durability``): ``step`` responses then
carry ``seq`` + a signed resumption ``token``, which the client tracks
(:attr:`session_token`) alongside a bounded replay buffer of its last
``replay_window`` samples.  After losing the connection — or the whole
worker — open a NEW client and call :meth:`resume` with the old client's
token/buffer: the server restores the session from its latest snapshot
and the client transparently re-steps the buffered samples past the
snapshot position, so scores continue exactly as if nothing died.
"""
from __future__ import annotations

import json
import socket
import time
from collections import OrderedDict
from typing import Optional, Sequence

import numpy as np


class ReplayWindowExceededError(RuntimeError):
    """The server's snapshot is older than the oldest sample in the
    client's replay buffer — the gap cannot be replayed.  Raise the
    snapshot cadence or the client's ``replay_window``."""


class GatewayClientError(RuntimeError):
    """An ``ok: false`` response; ``.error`` holds the server-side
    exception name (e.g. ``"GatewayOverloadedError"``)."""

    def __init__(self, error: str, message: str):
        super().__init__(f"{error}: {message}")
        self.error = error
        self.message = message


class GatewayClient:
    """One connection to a running gateway server.

    >>> with GatewayClient(host, port) as client:
    ...     client.step(x_t)["running_error"]     # streaming session
    ...     client.end_session()["final"]
    ...     client.score(window)                  # one-shot (blocks on flush)
    ...     rids = [client.submit(w) for w in windows]   # concurrent
    ...     scores = [client.collect(r)["score"] for r in rids]
    """

    def __init__(self, host: str = "127.0.0.1", port: int = 0,
                 timeout: float = 30.0, replay_window: int = 256):
        self._sock = socket.create_connection((host, port), timeout=timeout)
        self._rfile = self._sock.makefile("rb")
        self._next_id = 0
        self._parked: dict = {}  # id -> response that arrived out of order
        # durability bookkeeping: the freshest resumption token plus the
        # last `replay_window` (seq -> sample) pairs, enough to re-step
        # past any snapshot at most `replay_window` steps behind
        self.replay_window = int(replay_window)
        self._token: Optional[str] = None
        self._seq = 0
        self._replay: "OrderedDict[int, list]" = OrderedDict()

    # -- wire --------------------------------------------------------------

    def _send(self, payload: dict) -> int:
        rid = self._next_id
        self._next_id += 1
        payload["id"] = rid
        self._sock.sendall((json.dumps(payload) + "\n").encode())
        return rid

    def _read_until(self, rid: int) -> dict:
        while rid not in self._parked:
            line = self._rfile.readline()
            if not line:
                raise ConnectionError("server closed the connection")
            resp = json.loads(line)
            if resp.get("id") is None and not resp.get("ok"):
                # connection-level failure (unparseable / over-long line):
                # the server answers without an id and hangs up — surface
                # its reason instead of a bare ConnectionError later
                raise GatewayClientError(
                    resp.get("error", "UnknownError"), resp.get("message", "")
                )
            self._parked[resp.get("id")] = resp
        return self._parked.pop(rid)

    def collect(self, rid: int) -> dict:
        """Block until request ``rid``'s response arrives; raises
        :class:`GatewayClientError` on ``ok: false``."""
        resp = self._read_until(rid)
        if not resp.get("ok"):
            raise GatewayClientError(
                resp.get("error", "UnknownError"), resp.get("message", "")
            )
        return resp

    def request(self, op: str, **fields) -> dict:
        """Send one request and wait for its response."""
        return self.collect(self._send({"op": op, **fields}))

    # -- streaming session -------------------------------------------------

    @property
    def session_token(self) -> Optional[str]:
        """The freshest resumption token (None before the first step, or
        on a server without durability)."""
        return self._token

    @property
    def session_seq(self) -> int:
        return self._seq

    def replay_buffer(self) -> list:
        """``(seq, sample)`` pairs this client could replay — hand these
        (with :attr:`session_token`) to a NEW client's :meth:`resume`
        when this one's connection/worker died."""
        return [(seq, list(x)) for seq, x in self._replay.items()]

    def _track(self, resp: dict, x: list) -> dict:
        if "token" in resp:
            self._token = resp["token"]
            self._seq = int(resp.get("seq", self._seq))
            if x is not None:
                self._replay[self._seq] = x
                while len(self._replay) > self.replay_window:
                    self._replay.popitem(last=False)
        return resp

    def step(self, x_t) -> dict:
        """Advance this connection's pool session one timestep; returns the
        response (``running_error`` and, when calibrated, ``alert``; with
        durability also ``seq`` + ``token``, tracked on the client)."""
        x = np.asarray(x_t, np.float32).tolist()
        return self._track(self.request("step", x=x), x)

    def end_session(self) -> dict:
        """Evict the session; returns the response (``final`` score).  On
        a durable server this CLOSES the session — its tokens stop
        resuming once old snapshots age out."""
        resp = self.request("close")
        self._token = None
        self._seq = 0
        self._replay.clear()
        return resp

    def resume(self, token: Optional[str] = None,
               replay: Optional[Sequence] = None) -> dict:
        """Revive a durable session on THIS connection from ``token``
        (default: this client's own last token — useful after a plain
        reconnect; pass the dead client's token/``replay_buffer()`` when
        migrating).  Replays buffered samples newer than the server's
        snapshot position, so the session continues exactly where the old
        connection stopped.  Returns ``{"seq": <position after replay>,
        "running_error": .., "replayed": <n>}``."""
        token = self._token if token is None else token
        if token is None:
            raise ValueError("no resumption token (pass one, or step first)")
        entries = (list(self._replay.items()) if replay is None
                   else [(int(s), list(x)) for s, x in replay])
        resp = self.request("resume", token=token)
        self._token = resp.get("token", token)
        base = self._seq = int(resp["seq"])
        todo = sorted((s, x) for s, x in entries if s > base)
        if todo:
            expect = list(range(base + 1, base + 1 + len(todo)))
            if [s for s, _ in todo] != expect:
                raise ReplayWindowExceededError(
                    f"snapshot is at seq {base} but the replay buffer "
                    f"covers {todo[0][0]}..{todo[-1][0]} — "
                    f"{todo[0][0] - base - 1} step(s) are unrecoverable"
                )
        self._replay = OrderedDict(
            (s, x) for s, x in entries if s <= base
        )
        out = dict(resp)
        for _, x in todo:
            out = self.step(x)
        return {
            "seq": self._seq,
            "running_error": out["running_error"],
            "replayed": len(todo),
            "alert": out.get("alert"),
        }

    # -- one-shot scoring --------------------------------------------------

    def submit(self, series, *, priority: Optional[int] = None,
               tenant: Optional[str] = None) -> int:
        """Fire a one-shot score request; returns its id for
        :meth:`collect` (responses arrive on the server's flush cadence).
        ``priority`` (0 = highest class) and ``tenant`` feed the server's
        admission controller when one is attached; both are omitted from
        the wire payload when None, so legacy traffic is byte-identical."""
        payload = {"op": "score",
                   "series": np.asarray(series, np.float32).tolist()}
        if priority is not None:
            payload["priority"] = int(priority)
        if tenant is not None:
            payload["tenant"] = str(tenant)
        return self._send(payload)

    def score(self, series, *, priority: Optional[int] = None,
              tenant: Optional[str] = None) -> float:
        """Submit one window and block for its score."""
        return float(self.collect(
            self.submit(series, priority=priority, tenant=tenant)
        )["score"])

    def traced_score(self, series) -> dict:
        """One-shot score carrying a trace id, returning the full span.

        The request's ``trace`` field opts the server into span capture
        (old servers simply ignore it — the field is additive); the
        response's ``trace.stages`` carries the server-side breakdown
        (``dispatch`` / ``queue_wait`` / ``assemble`` / ``compute``).
        Client-side this method measures ``serialize`` (ndarray -> JSON
        text) and attributes the end-to-end remainder to ``wire``
        (sockets + framing + readline), so the returned stages sum to the
        observed end-to-end wire latency.

        Returns ``{"score", "trace_id", "stages": {name: ms}, "e2e_ms",
        "server_ms", "alert"}``.
        """
        t0 = time.perf_counter()
        rid = self._next_id
        self._next_id += 1
        tid = f"c{rid:x}"
        body = json.dumps({
            "op": "score", "id": rid, "trace": tid,
            "series": np.asarray(series, np.float32).tolist(),
        })
        t_serialized = time.perf_counter()
        self._sock.sendall((body + "\n").encode())
        resp = self.collect(rid)
        e2e_ms = (time.perf_counter() - t0) * 1e3
        trace = resp.get("trace") or {}
        stages = {"serialize": (t_serialized - t0) * 1e3}
        stages.update(trace.get("stages") or {})
        # everything not attributed above is transit: kernel buffers,
        # framing, the reply's decode.  Clamped at 0 — server stages are
        # sub-intervals of the client's wait, so the remainder is
        # non-negative up to clock granularity.
        stages["wire"] = max(0.0, e2e_ms - sum(stages.values()))
        return {
            "score": float(resp["score"]),
            "trace_id": str(trace.get("id", tid)),
            "stages": stages,
            "e2e_ms": e2e_ms,
            "server_ms": trace.get("total_ms"),
            "alert": resp.get("alert"),
        }

    def score_many(self, windows: Sequence) -> list:
        """Submit every window up front (so the server can micro-batch
        them), then collect all scores in submission order."""
        rids = [self.submit(w) for w in windows]
        return [float(self.collect(rid)["score"]) for rid in rids]

    # -- control -----------------------------------------------------------

    def stats(self) -> dict:
        return self.request("stats")["stats"]

    def recalibrate(self, threshold: Optional[float]) -> dict:
        """Swap the server-side detection threshold live (None disables
        alerting); resident sessions keep serving."""
        return self.request("recalibrate", threshold=threshold)

    def ping(self) -> bool:
        return bool(self.request("ping")["ok"])

    # -- lifecycle ---------------------------------------------------------

    def close(self) -> None:
        try:
            self._rfile.close()
        finally:
            self._sock.close()

    def __enter__(self) -> "GatewayClient":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


__all__ = ["GatewayClient", "GatewayClientError", "ReplayWindowExceededError"]

"""Synchronous JSON-lines client for :class:`~repro.gateway.server.GatewayServer`.

Stdlib-socket counterpart of the wire protocol documented in
``server.py`` — used by the client example, the transport tests, the
smoke script and the ``gateway_transport`` benchmark.  One connection
carries at most one streaming session (the server maps connections to
pool sessions) plus any number of in-flight one-shot score requests.

Responses can arrive out of submission order (``score`` answers when the
server's micro-batcher flushes), so the client matches responses to
requests by ``id``: :meth:`submit` returns a request id immediately and
:meth:`collect` blocks until that id's response has been read, parking
any other responses it sees on the way.
"""
from __future__ import annotations

import json
import socket
from typing import Optional, Sequence

import numpy as np


class GatewayClientError(RuntimeError):
    """An ``ok: false`` response; ``.error`` holds the server-side
    exception name (e.g. ``"GatewayOverloadedError"``)."""

    def __init__(self, error: str, message: str):
        super().__init__(f"{error}: {message}")
        self.error = error
        self.message = message


class GatewayClient:
    """One connection to a running gateway server.

    >>> with GatewayClient(host, port) as client:
    ...     client.step(x_t)["running_error"]     # streaming session
    ...     client.end_session()["final"]
    ...     client.score(window)                  # one-shot (blocks on flush)
    ...     rids = [client.submit(w) for w in windows]   # concurrent
    ...     scores = [client.collect(r)["score"] for r in rids]
    """

    def __init__(self, host: str = "127.0.0.1", port: int = 0, timeout: float = 30.0):
        self._sock = socket.create_connection((host, port), timeout=timeout)
        self._rfile = self._sock.makefile("rb")
        self._next_id = 0
        self._parked: dict = {}  # id -> response that arrived out of order

    # -- wire --------------------------------------------------------------

    def _send(self, payload: dict) -> int:
        rid = self._next_id
        self._next_id += 1
        payload["id"] = rid
        self._sock.sendall((json.dumps(payload) + "\n").encode())
        return rid

    def _read_until(self, rid: int) -> dict:
        while rid not in self._parked:
            line = self._rfile.readline()
            if not line:
                raise ConnectionError("server closed the connection")
            resp = json.loads(line)
            if resp.get("id") is None and not resp.get("ok"):
                # connection-level failure (unparseable / over-long line):
                # the server answers without an id and hangs up — surface
                # its reason instead of a bare ConnectionError later
                raise GatewayClientError(
                    resp.get("error", "UnknownError"), resp.get("message", "")
                )
            self._parked[resp.get("id")] = resp
        return self._parked.pop(rid)

    def collect(self, rid: int) -> dict:
        """Block until request ``rid``'s response arrives; raises
        :class:`GatewayClientError` on ``ok: false``."""
        resp = self._read_until(rid)
        if not resp.get("ok"):
            raise GatewayClientError(
                resp.get("error", "UnknownError"), resp.get("message", "")
            )
        return resp

    def request(self, op: str, **fields) -> dict:
        """Send one request and wait for its response."""
        return self.collect(self._send({"op": op, **fields}))

    # -- streaming session -------------------------------------------------

    def step(self, x_t) -> dict:
        """Advance this connection's pool session one timestep; returns the
        response (``running_error`` and, when calibrated, ``alert``)."""
        return self.request("step", x=np.asarray(x_t, np.float32).tolist())

    def end_session(self) -> dict:
        """Evict the session; returns the response (``final`` score)."""
        return self.request("close")

    # -- one-shot scoring --------------------------------------------------

    def submit(self, series) -> int:
        """Fire a one-shot score request; returns its id for
        :meth:`collect` (responses arrive on the server's flush cadence)."""
        return self._send(
            {"op": "score", "series": np.asarray(series, np.float32).tolist()}
        )

    def score(self, series) -> float:
        """Submit one window and block for its score."""
        return float(self.request("score", series=np.asarray(
            series, np.float32).tolist())["score"])

    def score_many(self, windows: Sequence) -> list:
        """Submit every window up front (so the server can micro-batch
        them), then collect all scores in submission order."""
        rids = [self.submit(w) for w in windows]
        return [float(self.collect(rid)["score"]) for rid in rids]

    # -- control -----------------------------------------------------------

    def stats(self) -> dict:
        return self.request("stats")["stats"]

    def recalibrate(self, threshold: Optional[float]) -> dict:
        """Swap the server-side detection threshold live (None disables
        alerting); resident sessions keep serving."""
        return self.request("recalibrate", threshold=threshold)

    def ping(self) -> bool:
        return bool(self.request("ping")["ok"])

    # -- lifecycle ---------------------------------------------------------

    def close(self) -> None:
        try:
            self._rfile.close()
        finally:
            self._sock.close()

    def __enter__(self) -> "GatewayClient":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


__all__ = ["GatewayClient", "GatewayClientError"]

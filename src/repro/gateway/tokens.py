"""Signed wire-level resumption tokens for durable gateway sessions.

A token is the client's proof that it owns a durable stream: every
``step`` response carries a fresh one, and presenting it to ANY worker
(via the ``resume`` op) restores the session from the latest snapshot.
Tokens are bearer credentials — compact, stateless, verifiable by every
worker sharing the store's secret file — so resumption needs no session
registry and survives the issuing worker being SIGKILLed.

Format (three dot-separated fields, URL-safe)::

    rt1.<base64url(payload-json)>.<base64url(hmac-sha256(secret, "rt1." + payload))>

Payload fields: ``sid`` (durable session id), ``seq`` (timesteps the
session had observed when the token was minted), ``epoch`` (recalibration
epoch at mint time), ``iat``/``exp`` (issue / expiry, unix seconds;
``exp`` null when the signer has no TTL).

The secret is 32 random bytes persisted once per store directory
(``token.secret``, mode 0600) so every worker — including respawns —
verifies every other worker's tokens.  No jax imports here: this module
loads in the supervisor before workers boot.
"""
from __future__ import annotations

import base64
import hashlib
import hmac
import json
import os
import time
from dataclasses import dataclass
from pathlib import Path
from typing import Callable, Optional

TOKEN_VERSION = "rt1"
SECRET_FILENAME = "token.secret"
_SECRET_BYTES = 32


class TokenError(ValueError):
    """Base class for resumption-token rejections.  The class NAME is the
    wire-level error code (``error`` field of the refusal response)."""


class TamperedTokenError(TokenError):
    """Signature mismatch or unparseable structure — the token was not
    minted (as presented) by any worker holding this store's secret."""


class ExpiredTokenError(TokenError):
    """Authentic token past its ``exp`` timestamp."""


class UnknownSessionError(TokenError):
    """Authentic, unexpired token whose session exists in no reachable
    snapshot — closed, expired out of the store, or never durable."""


@dataclass(frozen=True)
class SessionClaim:
    """The verified contents of a resumption token."""

    sid: str
    seq: int
    epoch: int
    issued_at: float
    expires_at: Optional[float]


def _b64e(raw: bytes) -> str:
    return base64.urlsafe_b64encode(raw).rstrip(b"=").decode("ascii")


def _b64d(text: str) -> bytes:
    pad = "=" * (-len(text) % 4)
    return base64.urlsafe_b64decode(text + pad)


def load_or_create_secret(directory: str | Path) -> bytes:
    """The store's shared signing secret, created atomically on first use
    (``os.O_EXCL`` — concurrent worker boots race safely, one wins and the
    rest read the winner's bytes)."""
    directory = Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    path = directory / SECRET_FILENAME
    if not path.exists():
        try:
            fd = os.open(path, os.O_WRONLY | os.O_CREAT | os.O_EXCL, 0o600)
        except FileExistsError:
            pass
        else:
            with os.fdopen(fd, "wb") as fh:
                fh.write(os.urandom(_SECRET_BYTES))
    secret = path.read_bytes()
    if len(secret) < 16:
        raise TokenError(f"secret file {path} is too short to be trusted")
    return secret


class TokenSigner:
    """Mints and verifies resumption tokens with one shared secret.

    ``ttl_s=None`` disables expiry; ``clock`` is injectable for tests.
    Verification order matters: structure/signature first (tampered), then
    expiry — an attacker must not learn whether a forged token's payload
    was otherwise plausible.
    """

    def __init__(self, secret: bytes, *, ttl_s: Optional[float] = 3600.0,
                 clock: Callable[[], float] = time.time):
        if not secret:
            raise ValueError("empty token secret")
        self._secret = bytes(secret)
        self.ttl_s = ttl_s
        self._clock = clock

    def _sign(self, payload_b64: str) -> str:
        mac = hmac.new(
            self._secret,
            f"{TOKEN_VERSION}.{payload_b64}".encode("ascii"),
            hashlib.sha256,
        ).digest()
        return _b64e(mac)

    def issue(self, sid: str, seq: int, epoch: int = 0) -> str:
        now = self._clock()
        payload = {
            "sid": str(sid),
            "seq": int(seq),
            "epoch": int(epoch),
            "iat": round(now, 3),
            "exp": None if self.ttl_s is None else round(now + self.ttl_s, 3),
        }
        payload_b64 = _b64e(
            json.dumps(payload, separators=(",", ":")).encode("utf-8")
        )
        return f"{TOKEN_VERSION}.{payload_b64}.{self._sign(payload_b64)}"

    def verify(self, token: str) -> SessionClaim:
        """Returns the claim or raises :class:`TamperedTokenError` /
        :class:`ExpiredTokenError`."""
        if not isinstance(token, str):
            raise TamperedTokenError("token must be a string")
        parts = token.split(".")
        if len(parts) != 3 or parts[0] != TOKEN_VERSION:
            raise TamperedTokenError("malformed resumption token")
        _, payload_b64, sig = parts
        if not hmac.compare_digest(sig, self._sign(payload_b64)):
            raise TamperedTokenError("resumption token signature mismatch")
        try:
            payload = json.loads(_b64d(payload_b64).decode("utf-8"))
            claim = SessionClaim(
                sid=str(payload["sid"]),
                seq=int(payload["seq"]),
                epoch=int(payload.get("epoch", 0)),
                issued_at=float(payload.get("iat", 0.0)),
                expires_at=(None if payload.get("exp") is None
                            else float(payload["exp"])),
            )
        except (ValueError, KeyError, TypeError) as e:
            # signature verified but payload undecodable: a signer bug or a
            # version skew, still refuse as tampered (never half-trust)
            raise TamperedTokenError(f"undecodable token payload: {e}") from e
        if claim.expires_at is not None and self._clock() > claim.expires_at:
            raise ExpiredTokenError(
                f"resumption token for {claim.sid!r} expired "
                f"{self._clock() - claim.expires_at:.1f}s ago"
            )
        return claim
